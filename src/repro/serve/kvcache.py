"""Paged, copy-on-write KV cache — the device-side "process memory".

The pool is the TPU analogue of the kernel page table + physical pages:

* **Pool**: per attention layer, ``(P, page_size, KVH, Hd)`` K and V arrays
  (stacked per stage/period to match the model's scan structure).  Page 0 is
  reserved as the filler entry for inactive page-table slots.
* **Page tables**: per session, ``(max_pages,)`` int32 on host.  Fork = copy
  the table + bump refcounts — O(pages) integers, zero HBM traffic: the
  ``fork()``-duplicates-page-tables-only analogue.
* **CoW**: the decode step writes in place, so before each step the manager
  *privatizes* every session's write-target page whose refcount > 1:
  allocate a free page, ``kernels.page_copy`` the contents (batched across
  layers via the stacked pool), swap the table entry.  ``warm`` runs the
  same privatization off the critical path (async-warm, §4.2.2).
* **Refcount GC**: releasing a session/template decrefs its pages; pages at
  refcount 0 return to the free list.

Host-side bookkeeping is numpy; device pools are jnp arrays functionally
updated (donated on TPU, so updates are in place).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.delta_pipeline import ChunkedView, DeltaGeneration
from repro.kernels import ops as kops

__all__ = ["PagePool", "PagedSession"]


class PagePool:
    """Global page pool + refcounts + free list for one served model."""

    def __init__(
        self,
        cfg: ModelConfig,
        *,
        num_pages: int,
        page_size: int = 16,
        max_pages_per_session: int = 32,
        dtype: Optional[str] = None,
    ):
        self.cfg = cfg
        self.page_size = page_size
        self.num_pages = num_pages
        self.max_pages = max_pages_per_session
        dt = jnp.dtype(dtype or cfg.dtype)
        # stage -> tag -> stacked (N_periods, P, psz, KVH, Hd)
        self.pools_k: Dict[str, Dict[str, jax.Array]] = {}
        self.pools_v: Dict[str, Dict[str, jax.Array]] = {}
        self.attn_tags: List[Tuple[str, str]] = []
        for i, stage in enumerate(cfg.stages):
            sk, sv = {}, {}
            for li, layer in enumerate(stage.period):
                for si, kind in enumerate(layer):
                    if kind in ("attn", "attn_local"):
                        tag = f"l{li}_s{si}_{kind}"
                        shape = (stage.n_periods, num_pages, page_size, cfg.n_kv_heads, cfg.head_dim)
                        sk[tag] = jnp.zeros(shape, dt)
                        sv[tag] = jnp.zeros(shape, dt)
                        self.attn_tags.append((f"stage{i}", tag))
            self.pools_k[f"stage{i}"] = sk
            self.pools_v[f"stage{i}"] = sv
        self.refs = np.zeros((num_pages,), np.int64)
        self.refs[0] = 1                       # page 0 reserved (filler)
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._lock = threading.RLock()
        self.cow_copies = 0                    # privatizations on the step path
        self.warm_copies = 0                   # privatizations absorbed by warm

    # --------------------------------------------------------- page algebra
    def alloc(self) -> int:
        with self._lock:
            if not self._free:
                raise MemoryError("page pool exhausted")
            p = self._free.pop()
            assert self.refs[p] == 0
            self.refs[p] = 1
            return p

    def incref(self, pages: np.ndarray) -> None:
        with self._lock:
            for p in pages:
                if p:
                    self.refs[p] += 1

    def decref(self, pages: np.ndarray) -> None:
        with self._lock:
            for p in pages:
                if p:
                    self.refs[p] -= 1
                    assert self.refs[p] >= 0, f"page {p} refcount underflow"
                    if self.refs[p] == 0:
                        self._free.append(int(p))

    def free_pages(self) -> int:
        with self._lock:
            return len(self._free)

    def used_bytes(self) -> int:
        """Physical bytes attributable to live (referenced) pages."""
        live = int(np.sum(self.refs[1:] > 0))
        bytes_per_page = sum(
            int(np.prod(self.pools_k[s][t].shape[2:])) * self.pools_k[s][t].dtype.itemsize * 2
            * self.pools_k[s][t].shape[0]
            for s, t in self.attn_tags
        )
        return live * bytes_per_page

    # ------------------------------------------------------------ CoW copy
    def copy_pages(self, src: List[int], dst: List[int]) -> None:
        """Materialize CoW copies pool-wide (all layers) for (src, dst) pairs."""
        if not src:
            return
        si = jnp.asarray(src, jnp.int32)
        di = jnp.asarray(dst, jnp.int32)
        for skey, tag in self.attn_tags:
            pk = self.pools_k[skey][tag]
            pv = self.pools_v[skey][tag]
            # stacked periods: copy within each period's pool slice
            self.pools_k[skey][tag] = jax.vmap(lambda p: kops.page_copy(p, si, di))(pk)
            self.pools_v[skey][tag] = jax.vmap(lambda p: kops.page_copy(p, si, di))(pv)

    # --------------------------------------------------- device page access
    def gather_page(self, page: int) -> Dict[str, np.ndarray]:
        """Host copy of one page across all layers (debug/test path)."""
        out = {}
        for skey, tag in self.attn_tags:
            out[f"{skey}/{tag}/k"] = np.asarray(self.pools_k[skey][tag][:, page])
            out[f"{skey}/{tag}/v"] = np.asarray(self.pools_v[skey][tag][:, page])
        return out

    def scatter_page(self, page: int, payload: Dict[str, np.ndarray]) -> None:
        """Write one page across all layers (debug/test path)."""
        for skey, tag in self.attn_tags:
            k = jnp.asarray(payload[f"{skey}/{tag}/k"])
            v = jnp.asarray(payload[f"{skey}/{tag}/v"])
            self.pools_k[skey][tag] = self.pools_k[skey][tag].at[:, page].set(k)
            self.pools_v[skey][tag] = self.pools_v[skey][tag].at[:, page].set(v)

    def gather_pages_device(self, pages: np.ndarray) -> Dict[str, jax.Array]:
        """One device gather per layer: ``kv/<stage>/<tag>/{k,v}`` →
        ``(n_pages, n_periods, page_size, KVH, Hd)`` device arrays.

        Stays on device — the dump pipeline diffs these in place and only
        dirty pages ever cross to the host."""
        idx = jnp.asarray(pages, jnp.int32)
        out: Dict[str, jax.Array] = {}
        for skey, tag in self.attn_tags:
            out[f"kv/{skey}/{tag}/k"] = jnp.moveaxis(self.pools_k[skey][tag][:, idx], 1, 0)
            out[f"kv/{skey}/{tag}/v"] = jnp.moveaxis(self.pools_v[skey][tag][:, idx], 1, 0)
        return out

    def scatter_pages(self, pages: np.ndarray, payload: Dict[str, np.ndarray]) -> None:
        """Vectorized inverse of ``gather_pages_device`` (slow-path restore)."""
        idx = jnp.asarray(pages, jnp.int32)
        for skey, tag in self.attn_tags:
            k = jnp.moveaxis(jnp.asarray(payload[f"kv/{skey}/{tag}/k"]), 0, 1)
            v = jnp.moveaxis(jnp.asarray(payload[f"kv/{skey}/{tag}/v"]), 0, 1)
            self.pools_k[skey][tag] = self.pools_k[skey][tag].at[:, idx].set(
                k.astype(self.pools_k[skey][tag].dtype)
            )
            self.pools_v[skey][tag] = self.pools_v[skey][tag].at[:, idx].set(
                v.astype(self.pools_v[skey][tag].dtype)
            )


class PagedSession:
    """A forkable agent session: page table + recurrent/host extras.

    Implements the DeltaCR ``ForkableState`` protocol; the "process memory"
    of one search-tree node.
    """

    def __init__(
        self,
        pool: PagePool,
        *,
        table: Optional[np.ndarray] = None,
        seq_len: int = 0,
        extras: Optional[Dict[str, Any]] = None,
        tokens: Optional[List[int]] = None,
    ):
        self.pool = pool
        self.table = table if table is not None else np.zeros((pool.max_pages,), np.int32)
        self.seq_len = int(seq_len)
        # extras: recurrent states (immutable jnp arrays -> alias on fork),
        # sampling rng, last token, conversation metadata...
        self.extras: Dict[str, Any] = dict(extras or {})
        self.tokens: List[int] = list(tokens or [])
        self._released = False
        # page positions written since the lineage was last marked clean;
        # None = unknown (delta dumps treat every page as dirty)
        self._dirty_pages: Optional[set] = None
        self._dirty_base: Optional[int] = None   # ckpt the set is relative to

    # ------------------------------------------------------------ utility
    @property
    def n_pages(self) -> int:
        return -(-self.seq_len // self.pool.page_size) if self.seq_len else 0

    def active_pages(self) -> np.ndarray:
        return self.table[: self.n_pages]

    # ---------------------------------------------------- dirty tracking
    def reset_dirty_tracking(self, base_ckpt=None) -> None:
        self._dirty_pages = set()
        self._dirty_base = base_ckpt

    def invalidate_dirty_tracking(self) -> None:
        self._dirty_pages = None
        self._dirty_base = None

    def dirty_tracking_base(self):
        return self._dirty_base if self._dirty_pages is not None else None

    # ------------------------------------------------------- ForkableState
    def fork(self) -> "PagedSession":
        self.pool.incref(self.active_pages())
        clone = PagedSession(
            self.pool,
            table=self.table.copy(),
            seq_len=self.seq_len,
            extras=dict(self.extras),     # jnp arrays alias (immutable)
            tokens=list(self.tokens),
        )
        clone._dirty_pages = None if self._dirty_pages is None else set(self._dirty_pages)
        clone._dirty_base = self._dirty_base
        return clone

    def release(self) -> None:
        if self._released:
            return
        self._released = True
        self.pool.decref(self.active_pages())

    def warm(self) -> None:
        """Pre-privatize the tail page off the critical path (async-warm).

        ensure_writable(warm=True) already accounts pool.warm_copies."""
        self.ensure_writable(warm=True)

    def dump_payload(self) -> Dict[str, np.ndarray]:
        payload: Dict[str, np.ndarray] = {
            "meta/seq_len": np.asarray([self.seq_len], np.int64),
            "meta/tokens": np.asarray(self.tokens, np.int64),
        }
        if self.n_pages:
            for name, dev in self.pool.gather_pages_device(self.active_pages()).items():
                payload[name] = np.asarray(dev)
        for name, val in self.extras.items():
            payload[f"extra/{name}"] = np.asarray(val)
        return payload

    @staticmethod
    def restore_from_payload(pool: PagePool, payload: Dict[str, np.ndarray]) -> "PagedSession":
        seq_len = int(payload["meta/seq_len"][0])
        tokens = [int(t) for t in payload["meta/tokens"]]
        sess = PagedSession(pool, seq_len=seq_len, tokens=tokens)
        n_pages = sess.n_pages
        if n_pages:
            for pos in range(n_pages):
                sess.table[pos] = pool.alloc()
            pool.scatter_pages(
                sess.active_pages(),
                {k: v for k, v in payload.items() if k.startswith("kv/")},
            )
        for name, arr in payload.items():
            if name.startswith("extra/"):
                sess.extras[name[len("extra/"):]] = jnp.asarray(arr)
        return sess

    # ------------------------------------------------------ DeltaEncodable
    def delta_generation(self, chunk_bytes: int) -> DeltaGeneration:
        """Chunked views with one chunk per KV page, entirely on device.

        The dump pipeline diffs these grids against the parent generation
        with ``kernels.delta_encode``; pages the dirty hint clears never get
        gathered at all, and only compacted dirty pages cross device→host.
        """
        del chunk_bytes  # KV chunk granularity is the page, not the store's
        extras: Dict[str, np.ndarray] = {
            "meta/seq_len": np.asarray([self.seq_len], np.int64),
            "meta/tokens": np.asarray(self.tokens, np.int64),
        }
        for name, val in self.extras.items():
            extras[f"extra/{name}"] = np.asarray(val)
        views: Dict[str, ChunkedView] = {}
        n_pages = self.n_pages
        if n_pages:
            pages = self.active_pages().copy()
            pool = self.pool
            for skey, tag in pool.attn_tags:
                proto = pool.pools_k[skey][tag]
                periods, _, psz, kvh, hd = proto.shape
                shape = (n_pages, periods, psz, kvh, hd)
                row_elems = periods * psz * kvh * hd
                row_bytes = row_elems * proto.dtype.itemsize
                for kv in ("k", "v"):
                    key = f"kv/{skey}/{tag}/{kv}"

                    def build(p=pool, s=skey, t=tag, which=kv, idx=pages, n=n_pages):
                        pools = p.pools_k if which == "k" else p.pools_v
                        dev = jnp.moveaxis(pools[s][t][:, jnp.asarray(idx, jnp.int32)], 1, 0)
                        flat = dev.reshape(n, -1)
                        return jax.lax.bitcast_convert_type(flat, jnp.uint8).reshape(n, -1)

                    views[key] = ChunkedView(
                        shape=shape,
                        dtype=str(proto.dtype),
                        nbytes=n_pages * row_bytes,
                        chunk_bytes=row_bytes,
                        n_chunks=n_pages,
                        trailing_pad=0,
                        grid_fn=build,
                    )
        if self._dirty_pages is None:
            dirty_keys = None
        else:
            # meta/extras churn every step and are tiny: always dirty.  KV
            # grids are dirty only if some page position was written.
            dirty_keys = frozenset(extras)
            if self._dirty_pages:
                dirty_keys = dirty_keys | frozenset(views)
        return DeltaGeneration(views=views, extras=extras, dirty_keys=dirty_keys)

    # --------------------------------------------------------------- write
    def ensure_writable(self, *, warm: bool = False, extra_tokens: int = 1) -> int:
        """Guarantee the next ``extra_tokens`` appends hit exclusively-owned
        pages.  Returns the number of CoW copies performed.

        This is the CoW fault (inline) or its async-warm pre-payment.
        """
        psz = self.pool.page_size
        copies_src, copies_dst = [], []
        new_len = self.seq_len + extra_tokens
        first_page = self.seq_len // psz
        last_page = (new_len - 1) // psz
        if self._dirty_pages is not None:
            # every position in the write window is about to change content
            self._dirty_pages.update(range(first_page, last_page + 1))
        for pos in range(first_page, last_page + 1):
            if pos >= len(self.table):
                raise MemoryError("session exceeded max_pages")
            page = int(self.table[pos])
            needed = pos * psz < new_len
            if not needed:
                continue
            if pos * psz >= self.seq_len and (page == 0 or self.pool.refs[page] == 0):
                # fresh page boundary: plain allocation, no copy
                self.table[pos] = self.pool.alloc()
            elif self.pool.refs[page] > 1:
                # shared page: CoW privatize
                new_page = self.pool.alloc()
                copies_src.append(page)
                copies_dst.append(new_page)
                self.table[pos] = new_page
        if copies_src:
            self.pool.copy_pages(copies_src, copies_dst)
            self.pool.decref(np.asarray(copies_src))
            if warm:
                self.pool.warm_copies += len(copies_src)
            else:
                self.pool.cow_copies += len(copies_src)
        return len(copies_src)

    def resident_bytes(self) -> int:
        """Footprint attributable to this session (shared pages amortized)."""
        psz_bytes = 0
        for skey, tag in self.pool.attn_tags:
            p = self.pool.pools_k[skey][tag]
            psz_bytes += int(np.prod(p.shape[2:])) * p.dtype.itemsize * 2 * p.shape[0]
        total = 0.0
        for pos in range(self.n_pages):
            page = int(self.table[pos])
            if page:
                total += psz_bytes / max(int(self.pool.refs[page]), 1)
        return int(total)
