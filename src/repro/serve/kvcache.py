"""Paged, copy-on-write KV cache — the device-side "process memory".

The pool is the TPU analogue of the kernel page table + physical pages:

* **Pool**: per attention layer, ``(P, page_size, KVH, Hd)`` K and V arrays
  (stacked per stage/period to match the model's scan structure).  Page 0 is
  reserved as the filler entry for inactive page-table slots.
* **Page tables**: per session, ``(max_pages,)`` int32 on host.  Fork = copy
  the table + bump refcounts — O(pages) integers, zero HBM traffic: the
  ``fork()``-duplicates-page-tables-only analogue.
* **CoW**: the decode step writes in place, so before each step the manager
  *privatizes* every session's write-target page whose refcount > 1:
  allocate a free page, ``kernels.page_copy`` the contents (batched across
  layers via the stacked pool), swap the table entry.  ``warm`` runs the
  same privatization off the critical path (async-warm, §4.2.2).
* **Refcount GC**: releasing a session/template decrefs its pages; pages at
  refcount 0 return to the free list.

Host-side bookkeeping is numpy; device pools are jnp arrays functionally
updated (donated on TPU, so updates are in place).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import faults
from repro.core.delta_pipeline import ChunkedView, DeltaGeneration
from repro.dist import shard_dump as _sd
from repro.kernels import ops as kops

__all__ = [
    "CowCorruptionError",
    "CowFaultError",
    "PagePool",
    "PagedSession",
    "PoolStats",
    "WritePlan",
]


class CowFaultError(RuntimeError):
    """A CoW materialization failed and was rolled back (no table mutated)."""


class CowCorruptionError(CowFaultError):
    """Verified CoW copy mismatched its source; the batch was rolled back."""


@dataclass
class PoolStats:
    """Block accounting for the pool — the serving-side analogue of the
    ChunkStore's byte accounting: forks are free until the first write, and
    these counters prove it (tests/benchmarks gate on them).
    """

    cow_copies: int = 0        # pages privatized on the step path
    warm_copies: int = 0       # pages privatized by async-warm
    copied_pages: int = 0      # total pages materialized (cow + warm)
    copied_bytes: int = 0      # bytes moved by CoW materialization
    fresh_allocs: int = 0      # fresh page-boundary allocations (no copy)
    materialize_calls: int = 0 # batched materialization rounds (≤1/step)
    cow_rollbacks: int = 0     # failed materializations fully rolled back
    stale_discards: int = 0    # plans that lost a same-session race (warm
                               # vs step) and were discarded at commit time


@dataclass
class WritePlan:
    """One session's planned page motion for an upcoming write window.

    Built by :meth:`PagedSession.plan_writable` (pages are *allocated* but
    no table entry, refcount-decref, or dirty set has been touched), then
    either committed or rolled back atomically — across a whole batch — by
    :meth:`PagePool.materialize`.
    """

    session: "PagedSession"
    fresh: List[Tuple[int, int]]        # (table pos, newly allocated page)
    cow: List[Tuple[int, int, int]]     # (table pos, shared src, private dst)
    window: Tuple[int, int]             # (first_page, last_page) dirty span


class PagePool:
    """Global page pool + refcounts + free list for one served model."""

    def __init__(
        self,
        cfg: ModelConfig,
        *,
        num_pages: int,
        page_size: int = 16,
        max_pages_per_session: int = 32,
        dtype: Optional[str] = None,
        verify_cow: bool = False,
        sharding: Optional[Any] = None,
    ):
        self.cfg = cfg
        self.page_size = page_size
        self.num_pages = num_pages
        self.max_pages = max_pages_per_session
        dt = jnp.dtype(dtype or cfg.dtype)
        # Optional placement for the device pools, over the stacked pool
        # axes (n_periods, P, page_size, KVH, Hd).  Shard the head/feature
        # axes (tensor parallelism); leave the page axis (axis 1)
        # unsharded — page gathers index it with host-chosen page lists and
        # must stay shard-local for the gather-free dump path.
        self.sharding = sharding
        # stage -> tag -> stacked (N_periods, P, psz, KVH, Hd)
        self.pools_k: Dict[str, Dict[str, jax.Array]] = {}
        self.pools_v: Dict[str, Dict[str, jax.Array]] = {}
        self.attn_tags: List[Tuple[str, str]] = []
        for i, stage in enumerate(cfg.stages):
            sk, sv = {}, {}
            for li, layer in enumerate(stage.period):
                for si, kind in enumerate(layer):
                    if kind in ("attn", "attn_local"):
                        tag = f"l{li}_s{si}_{kind}"
                        shape = (stage.n_periods, num_pages, page_size, cfg.n_kv_heads, cfg.head_dim)
                        sk[tag] = jnp.zeros(shape, dt)
                        sv[tag] = jnp.zeros(shape, dt)
                        if sharding is not None:
                            sk[tag] = jax.device_put(sk[tag], sharding)
                            sv[tag] = jax.device_put(sv[tag], sharding)
                        self.attn_tags.append((f"stage{i}", tag))
            self.pools_k[f"stage{i}"] = sk
            self.pools_v[f"stage{i}"] = sv
        self.refs = np.zeros((num_pages,), np.int64)
        self.refs[0] = 1                       # page 0 reserved (filler)
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._lock = threading.RLock()
        self.stats = PoolStats()
        # re-read every materialized dst page against its src and roll the
        # batch back on mismatch (bitrot on the copy path); costs a device
        # round-trip per batch, so off outside chaos/validation runs
        self.verify_cow = bool(verify_cow)
        self._bytes_per_page = sum(
            int(np.prod(self.pools_k[s][t].shape[2:])) * self.pools_k[s][t].dtype.itemsize * 2
            * self.pools_k[s][t].shape[0]
            for s, t in self.attn_tags
        )

    @property
    def lock(self) -> threading.RLock:
        """Pool mutation lock (reentrant).  Holders get exclusive access to
        the device pool arrays *and* the host bookkeeping: the engine wraps
        each step's read→decode→write-back window in it so a concurrent
        async-warm materialize can never be lost to the step's functional
        cache update."""
        return self._lock

    # ------------------------------------------------- back-compat counters
    @property
    def cow_copies(self) -> int:
        return self.stats.cow_copies

    @property
    def warm_copies(self) -> int:
        return self.stats.warm_copies

    def bytes_per_page(self) -> int:
        """Physical bytes one page occupies across every layer's K+V pools."""
        return self._bytes_per_page

    def multi_device(self) -> bool:
        """True when the pools are spread over more than one device."""
        if self.sharding is None:
            return False
        return len(getattr(self.sharding, "device_set", ())) > 1

    def grid_sharding(self) -> Optional[Any]:
        """Placement for gathered page grids ``(n_pages, periods, psz, KVH,
        Hd)``, derived from the pool sharding ``(periods, P, psz, KVH, Hd)``:
        the page axis becomes the (unsharded) leading axis and the remaining
        axes keep their pool placement.  None when the pool is unsharded or
        the sharding carries no NamedSharding-style mesh/spec."""
        sh = self.sharding
        spec = getattr(sh, "spec", None)
        mesh = getattr(sh, "mesh", None)
        if sh is None or spec is None or mesh is None:
            return None
        from jax.sharding import NamedSharding, PartitionSpec

        s = list(spec) + [None] * max(0, 5 - len(tuple(spec)))
        return NamedSharding(mesh, PartitionSpec(None, s[0], s[2], s[3], s[4]))

    # --------------------------------------------------------- page algebra
    def alloc(self) -> int:
        with self._lock:
            if not self._free:
                raise MemoryError("page pool exhausted")
            p = self._free.pop()
            assert self.refs[p] == 0
            self.refs[p] = 1
            return p

    def incref(self, pages: np.ndarray) -> None:
        with self._lock:
            for p in pages:
                if p:
                    self.refs[p] += 1

    def decref(self, pages: np.ndarray) -> None:
        with self._lock:
            for p in pages:
                if p:
                    self.refs[p] -= 1
                    assert self.refs[p] >= 0, f"page {p} refcount underflow"
                    if self.refs[p] == 0:
                        self._free.append(int(p))

    def free_pages(self) -> int:
        with self._lock:
            return len(self._free)

    def used_bytes(self) -> int:
        """Physical bytes attributable to live (referenced) pages."""
        live = int(np.sum(self.refs[1:] > 0))
        return live * self._bytes_per_page

    def debug_validate(self) -> None:
        """Allocator invariants: refs never negative, the free list and the
        refcount table partition the page space exactly."""
        with self._lock:
            assert np.all(self.refs >= 0), "negative page refcount"
            free = set(self._free)
            assert len(free) == len(self._free), "duplicate page on free list"
            for p in range(1, self.num_pages):
                if self.refs[p] == 0:
                    assert p in free, f"page {p} dead but not on the free list"
                else:
                    assert p not in free, f"page {p} live but on the free list"
            assert self.refs[0] == 1 and 0 not in free, "filler page 0 corrupted"

    # ------------------------------------------------------------ CoW copy
    def copy_pages(self, src: List[int], dst: List[int]) -> None:
        """Materialize CoW copies pool-wide (all layers) for (src, dst) pairs.

        One stacked-kernel launch per (stage, tag, k/v) — the whole batch of
        pairs, all scan periods, in a single ``kernels.page_copy_stacked``
        call each."""
        if not src:
            return
        si = jnp.asarray(src, jnp.int32)
        di = jnp.asarray(dst, jnp.int32)
        for skey, tag in self.attn_tags:
            pk = self.pools_k[skey][tag]
            pv = self.pools_v[skey][tag]
            self.pools_k[skey][tag] = kops.page_copy_stacked(pk, si, di)
            self.pools_v[skey][tag] = kops.page_copy_stacked(pv, si, di)

    # ------------------------------------------- transactional CoW batching
    def materialize(self, plans: Sequence["WritePlan"], *, warm: bool = False) -> int:
        """Commit a batch of write plans atomically; returns pages copied.

        The serving loop's CoW fault handler: every plan's shared pages are
        privatized in one batched ``copy_pages`` launch, then — only after
        the copies landed (and verified, when ``verify_cow``) — the page
        tables swap, the shared sources decref, and dirty tracking records
        the write windows.  Any failure (allocator, kernel, injected fault,
        verification mismatch) frees every page the batch allocated and
        leaves every session's table, refcounts, and dirty sets exactly as
        they were: a decode step either lands or aborts loudly with refs
        rolled back.  Fault seam: ``kvcache.cow_copy``.

        The whole call holds the pool lock: the async-warm worker and the
        step path both materialize against the same device pools, and an
        unserialized warm commit landing mid-step would be overwritten by
        the step's functional cache update (lost-update on the dst page).
        Plans are also *revalidated* here — two plans for the same session
        (warm racing the step) both privatize the same table slot, and the
        loser must discard its page instead of double-decreffing the source.
        """
        plans = [p for p in plans if p.fresh or p.cow]
        if not plans:
            return 0
        with self._lock:
            # revalidate against the current tables: a plan built before an
            # earlier materialize committed may have lost its slot already
            stale: List[int] = []
            live: List[Tuple[WritePlan, List[Tuple[int, int]], List[Tuple[int, int, int]]]] = []
            for p in plans:
                sess = p.session
                fresh_ok: List[Tuple[int, int]] = []
                cow_ok: List[Tuple[int, int, int]] = []
                for pos, page in p.fresh:
                    cur = int(sess.table[pos])
                    if cur != 0 and self.refs[cur] > 0:
                        stale.append(page)       # slot already privately owned
                    else:
                        fresh_ok.append((pos, page))
                for pos, s, d in p.cow:
                    if int(sess.table[pos]) != s:
                        stale.append(d)          # another plan privatized first
                    else:
                        cow_ok.append((pos, s, d))
                live.append((p, fresh_ok, cow_ok))
            src = [s for _, _, cow_ok in live for (_, s, _) in cow_ok]
            dst = [d for _, _, cow_ok in live for (_, _, d) in cow_ok]
            try:
                if src:
                    # raise-action faults fire before any device work; a
                    # corrupt-action fault mangles the sentinel, and we model
                    # the bitrot by scribbling on one destination post-copy
                    blob = faults.fire("kvcache.cow_copy", b"\x00")
                    self.copy_pages(src, dst)
                    if blob is not None and blob != b"\x00":
                        self._corrupt_page_for_test(dst[0])
                    if self.verify_cow:
                        self._verify_copies(src, dst)
            except BaseException:
                self.stats.cow_rollbacks += 1
                self.discard_plans(plans)
                raise
            # -------------------------------------------------------- commit
            for p, fresh_ok, cow_ok in live:
                sess = p.session
                for pos, page in fresh_ok:
                    sess.table[pos] = page
                for pos, _s, d in cow_ok:
                    sess.table[pos] = d
                if cow_ok:
                    self.decref(np.asarray([s for _, s, _ in cow_ok], np.int64))
                if sess._dirty_pages is not None:
                    first, last = p.window
                    sess._dirty_pages.update(range(first, last + 1))
            if stale:
                self.decref(np.asarray(stale, np.int64))
                self.stats.stale_discards += len(stale)
            n = len(src)
            self.stats.copied_pages += n
            self.stats.copied_bytes += n * self._bytes_per_page
            self.stats.fresh_allocs += sum(len(f) for _, f, _ in live)
            self.stats.materialize_calls += 1
            if warm:
                self.stats.warm_copies += n
            else:
                self.stats.cow_copies += n
        return n

    def discard_plans(self, plans: Sequence["WritePlan"]) -> None:
        """Return every page a set of uncommitted plans allocated."""
        for p in plans:
            taken = [pg for _, pg in p.fresh] + [d for _, _, d in p.cow]
            if taken:
                self.decref(np.asarray(taken, np.int64))

    def _corrupt_page_for_test(self, page: int) -> None:
        """Injected-bitrot analogue for the copy path (chaos tests only)."""
        skey, tag = self.attn_tags[0]
        self.pools_k[skey][tag] = self.pools_k[skey][tag].at[:, page].add(1)

    def _verify_copies(self, src: List[int], dst: List[int]) -> None:
        si = jnp.asarray(src, jnp.int32)
        di = jnp.asarray(dst, jnp.int32)
        for skey, tag in self.attn_tags:
            for pools in (self.pools_k, self.pools_v):
                a = np.asarray(pools[skey][tag][:, si])
                b = np.asarray(pools[skey][tag][:, di])
                if not np.array_equal(a, b):
                    raise CowCorruptionError(
                        f"CoW copy mismatch in {skey}/{tag} (pairs {src}->{dst})"
                    )

    # --------------------------------------------------- device page access
    def gather_page(self, page: int) -> Dict[str, np.ndarray]:
        """Host copy of one page across all layers (debug/test path)."""
        out = {}
        for skey, tag in self.attn_tags:
            out[f"{skey}/{tag}/k"] = np.asarray(self.pools_k[skey][tag][:, page])
            out[f"{skey}/{tag}/v"] = np.asarray(self.pools_v[skey][tag][:, page])
        return out

    def scatter_page(self, page: int, payload: Dict[str, np.ndarray]) -> None:
        """Write one page across all layers (debug/test path)."""
        for skey, tag in self.attn_tags:
            k = jnp.asarray(payload[f"{skey}/{tag}/k"])
            v = jnp.asarray(payload[f"{skey}/{tag}/v"])
            self.pools_k[skey][tag] = self.pools_k[skey][tag].at[:, page].set(k)
            self.pools_v[skey][tag] = self.pools_v[skey][tag].at[:, page].set(v)

    def gather_pages_device(self, pages: np.ndarray) -> Dict[str, jax.Array]:
        """One device gather per layer: ``kv/<stage>/<tag>/{k,v}`` →
        ``(n_pages, n_periods, page_size, KVH, Hd)`` device arrays.

        Stays on device — the dump pipeline diffs these in place and only
        dirty pages ever cross to the host."""
        idx = jnp.asarray(pages, jnp.int32)
        out: Dict[str, jax.Array] = {}
        for skey, tag in self.attn_tags:
            out[f"kv/{skey}/{tag}/k"] = jnp.moveaxis(self.pools_k[skey][tag][:, idx], 1, 0)
            out[f"kv/{skey}/{tag}/v"] = jnp.moveaxis(self.pools_v[skey][tag][:, idx], 1, 0)
        return out

    def scatter_pages(self, pages: np.ndarray, payload: Dict[str, np.ndarray]) -> None:
        """Vectorized inverse of ``gather_pages_device`` (slow-path restore)."""
        with self._lock:
            self._scatter_pages_locked(pages, payload)

    def _scatter_pages_locked(self, pages: np.ndarray, payload: Dict[str, np.ndarray]) -> None:
        idx = jnp.asarray(pages, jnp.int32)
        for skey, tag in self.attn_tags:
            k = jnp.moveaxis(jnp.asarray(payload[f"kv/{skey}/{tag}/k"]), 0, 1)
            v = jnp.moveaxis(jnp.asarray(payload[f"kv/{skey}/{tag}/v"]), 0, 1)
            self.pools_k[skey][tag] = self.pools_k[skey][tag].at[:, idx].set(
                k.astype(self.pools_k[skey][tag].dtype)
            )
            self.pools_v[skey][tag] = self.pools_v[skey][tag].at[:, idx].set(
                v.astype(self.pools_v[skey][tag].dtype)
            )


class _TrackedExtras(dict):
    """Session extras dict that notes which top-level keys were written.

    Every rebind path (``[]=``, ``del``, ``update``, ``pop``, ``popitem``,
    ``setdefault``, ``clear``) records the touched key into the owning
    session's ``_dirty_extras`` set, giving ``delta_generation`` key-granular
    dirty hints for recurrent state (mamba/xlstm extras) without reading a
    byte of device memory.  The invariant callers must keep: values are
    rebound, never mutated in place — jnp arrays are immutable and the
    engine rebinds whole recurrent-state subtrees, so a nested-``dict``
    value handed out by ``setdefault``/``[]`` must not be written through
    (the tracker cannot see it, exactly like writing through a stale page
    table).  ``setdefault`` conservatively marks its key dirty because the
    returned default is a candidate for exactly that kind of aliasing.
    """

    def __init__(self, owner: "PagedSession", data: Optional[Dict[str, Any]] = None):
        super().__init__(data or {})
        self._owner = owner

    def _note(self, key: Any) -> None:
        dirty = self._owner._dirty_extras
        if dirty is not None:
            dirty.add(key)

    def __setitem__(self, key, value):
        self._note(key)
        super().__setitem__(key, value)

    def __delitem__(self, key):
        self._note(key)
        super().__delitem__(key)

    def setdefault(self, key, default=None):
        self._note(key)
        return super().setdefault(key, default)

    def pop(self, key, *args):
        if key in self:
            self._note(key)
        return super().pop(key, *args)

    def popitem(self):
        key, val = super().popitem()
        self._note(key)
        return key, val

    def update(self, *args, **kwargs):
        incoming = dict(*args, **kwargs)
        for key in incoming:
            self._note(key)
        super().update(incoming)

    def clear(self):
        for key in list(self):
            self._note(key)
        super().clear()


class PagedSession:
    """A forkable agent session: page table + recurrent/host extras.

    Implements the DeltaCR ``ForkableState`` protocol; the "process memory"
    of one search-tree node.
    """

    def __init__(
        self,
        pool: PagePool,
        *,
        table: Optional[np.ndarray] = None,
        seq_len: int = 0,
        extras: Optional[Dict[str, Any]] = None,
        tokens: Optional[List[int]] = None,
    ):
        self.pool = pool
        self.table = table if table is not None else np.zeros((pool.max_pages,), np.int32)
        self.seq_len = int(seq_len)
        # top-level extras keys rebound since the lineage was last marked
        # clean; None = unknown (delta dumps treat every extra as dirty).
        # Must exist before the tracked dict below is constructed.
        self._dirty_extras: Optional[set] = None
        # extras: recurrent states (immutable jnp arrays -> alias on fork),
        # sampling rng, last token, conversation metadata...
        self.extras: Dict[str, Any] = _TrackedExtras(self, dict(extras or {}))
        self.tokens: List[int] = list(tokens or [])
        self._released = False
        # page positions written since the lineage was last marked clean;
        # None = unknown (delta dumps treat every page as dirty)
        self._dirty_pages: Optional[set] = None
        self._dirty_base: Optional[int] = None   # ckpt the set is relative to

    # ------------------------------------------------------------ utility
    @property
    def n_pages(self) -> int:
        return -(-self.seq_len // self.pool.page_size) if self.seq_len else 0

    def active_pages(self) -> np.ndarray:
        return self.table[: self.n_pages]

    # ---------------------------------------------------- dirty tracking
    def reset_dirty_tracking(self, base_ckpt=None) -> None:
        self._dirty_pages = set()
        self._dirty_extras = set()
        self._dirty_base = base_ckpt

    def invalidate_dirty_tracking(self) -> None:
        self._dirty_pages = None
        self._dirty_extras = None
        self._dirty_base = None

    def dirty_tracking_base(self):
        return self._dirty_base if self._dirty_pages is not None else None

    def _extras_nbytes(self) -> Dict[str, int]:
        """Per-top-level-key extras byte sizes, from ``nbytes`` alone — jnp
        and numpy arrays both expose it, so no device transfer happens."""

        def size(val: Any) -> int:
            if isinstance(val, dict):
                return sum(size(v) for v in val.values())
            nbytes = getattr(val, "nbytes", None)
            if nbytes is not None:
                return int(nbytes)
            return int(np.asarray(val).nbytes)

        return {name: size(val) for name, val in self.extras.items()}

    def dirty_fraction_hint(self) -> Optional[float]:
        """Byte-weighted fraction of the session's dumpable state (active KV
        pages + extras) written since the last mark-clean; None when
        tracking is invalid.  An upper bound on the per-grid dirty fraction
        (the adaptive selector's ratio calibration absorbs the scale), used
        to pick the dump mode per checkpoint.  Weighting by bytes means
        recurrent-only sessions (zero attention pages, all state in extras)
        report real churn instead of a constant 0.0."""
        if self._dirty_pages is None or self._dirty_extras is None:
            return None
        n = self.n_pages
        bpp = self.pool.bytes_per_page()
        sizes = self._extras_nbytes()
        total = n * bpp + sum(sizes.values())
        if total <= 0:
            return 0.0
        dirty = bpp * sum(1 for pos in self._dirty_pages if pos < n)
        dirty += sum(sizes.get(key, 0) for key in self._dirty_extras)
        return min(dirty / total, 1.0)

    # ------------------------------------------------------- ForkableState
    def fork(self) -> "PagedSession":
        self.pool.incref(self.active_pages())
        clone = PagedSession(
            self.pool,
            table=self.table.copy(),
            seq_len=self.seq_len,
            extras=dict(self.extras),     # jnp arrays alias (immutable)
            tokens=list(self.tokens),
        )
        clone._dirty_pages = None if self._dirty_pages is None else set(self._dirty_pages)
        clone._dirty_extras = None if self._dirty_extras is None else set(self._dirty_extras)
        clone._dirty_base = self._dirty_base
        return clone

    def release(self) -> None:
        if self._released:
            return
        self._released = True
        self.pool.decref(self.active_pages())

    def warm(self) -> None:
        """Pre-privatize the tail page off the critical path (async-warm).

        ensure_writable(warm=True) already accounts pool.warm_copies."""
        self.ensure_writable(warm=True)

    def _flat_extras(self) -> Dict[str, np.ndarray]:
        """Extras as flat numpy arrays.  Recurrent states live in extras as
        dicts of arrays (e.g. mamba ``{"conv", "ssm"}``); nested keys are
        joined with ``::`` — extras *names* already contain ``/``."""
        out: Dict[str, np.ndarray] = {}

        def walk(prefix: str, val: Any) -> None:
            if isinstance(val, dict):
                for k, v in val.items():
                    walk(f"{prefix}::{k}", v)
            else:
                out[prefix] = np.asarray(val)

        for name, val in self.extras.items():
            walk(name, val)
        return out

    def dump_payload(self) -> Dict[str, np.ndarray]:
        payload: Dict[str, np.ndarray] = {
            "meta/seq_len": np.asarray([self.seq_len], np.int64),
            "meta/tokens": np.asarray(self.tokens, np.int64),
        }
        if self.n_pages:
            for name, dev in self.pool.gather_pages_device(self.active_pages()).items():
                payload[name] = np.asarray(dev)
        for name, val in self._flat_extras().items():
            payload[f"extra/{name}"] = val
        return payload

    @staticmethod
    def restore_from_payload(pool: PagePool, payload: Dict[str, np.ndarray]) -> "PagedSession":
        seq_len = int(payload["meta/seq_len"][0])
        tokens = [int(t) for t in payload["meta/tokens"]]
        sess = PagedSession(pool, seq_len=seq_len, tokens=tokens)
        n_pages = sess.n_pages
        if n_pages:
            for pos in range(n_pages):
                sess.table[pos] = pool.alloc()
            pool.scatter_pages(
                sess.active_pages(),
                {k: v for k, v in payload.items() if k.startswith("kv/")},
            )
        for name, arr in payload.items():
            if not name.startswith("extra/"):
                continue
            path = name[len("extra/"):]
            if "::" in path:                     # nested recurrent-state dict
                head, *rest = path.split("::")
                node = sess.extras.setdefault(head, {})
                for part in rest[:-1]:
                    node = node.setdefault(part, {})
                node[rest[-1]] = jnp.asarray(arr)
            else:
                sess.extras[path] = jnp.asarray(arr)
        return sess

    # ------------------------------------------------------ DeltaEncodable
    def delta_generation(self, chunk_bytes: int) -> DeltaGeneration:
        """Chunked views with one chunk per KV page, entirely on device.

        The dump pipeline diffs these grids against the parent generation
        with ``kernels.delta_encode``; pages the dirty hint clears never get
        gathered at all, and only compacted dirty pages cross device→host.

        On a multi-device pool the gathered page grids are instead exposed
        as ``dist.shard_dump.ShardedView``s under the canonical mesh-
        independent ``TilePlan`` (``chunk_bytes`` sets the tile target), so
        the pipeline diffs/compacts each shard on its own device and only
        per-shard dirty tiles cross device→host — chunk ids and digests then
        match any other mesh layout of the same session state.
        """
        extras: Dict[str, np.ndarray] = {
            "meta/seq_len": np.asarray([self.seq_len], np.int64),
            "meta/tokens": np.asarray(self.tokens, np.int64),
        }
        for name, val in self._flat_extras().items():
            extras[f"extra/{name}"] = val
        views: Dict[str, Any] = {}
        n_pages = self.n_pages
        if n_pages:
            pages = self.active_pages().copy()
            pool = self.pool
            grid_shard = pool.grid_sharding() if pool.multi_device() else None
            for skey, tag in pool.attn_tags:
                proto = pool.pools_k[skey][tag]
                periods, _, psz, kvh, hd = proto.shape
                shape = (n_pages, periods, psz, kvh, hd)
                row_elems = periods * psz * kvh * hd
                row_bytes = row_elems * proto.dtype.itemsize
                for kv in ("k", "v"):
                    key = f"kv/{skey}/{tag}/{kv}"
                    if grid_shard is not None:
                        pools = pool.pools_k if kv == "k" else pool.pools_v
                        dev = jnp.moveaxis(
                            pools[skey][tag][:, jnp.asarray(pages, jnp.int32)], 1, 0
                        )
                        dev = jax.device_put(dev, grid_shard)
                        plan = _sd.TilePlan.for_array(
                            shape, str(proto.dtype), max(int(chunk_bytes), 1)
                        )
                        views[key] = _sd.sharded_view(dev, plan)
                        continue

                    def build(p=pool, s=skey, t=tag, which=kv, idx=pages, n=n_pages):
                        pools = p.pools_k if which == "k" else p.pools_v
                        dev = jnp.moveaxis(pools[s][t][:, jnp.asarray(idx, jnp.int32)], 1, 0)
                        flat = dev.reshape(n, -1)
                        return jax.lax.bitcast_convert_type(flat, jnp.uint8).reshape(n, -1)

                    views[key] = ChunkedView(
                        shape=shape,
                        dtype=str(proto.dtype),
                        nbytes=n_pages * row_bytes,
                        chunk_bytes=row_bytes,
                        n_chunks=n_pages,
                        trailing_pad=0,
                        grid_fn=build,
                    )
        if self._dirty_pages is None or self._dirty_extras is None:
            dirty_keys = None
        else:
            # Session metadata churns every step and is tiny: always dirty.
            # Extras are dirty at top-level-key granularity (the tracked
            # dict notes every rebind); KV grids only if some page position
            # was written.
            dirty = {"meta/seq_len", "meta/tokens"}
            for key in extras:
                if key.startswith("extra/"):
                    head = key[len("extra/"):].split("::", 1)[0]
                    if head in self._dirty_extras:
                        dirty.add(key)
            if self._dirty_pages:
                dirty.update(views)
            dirty_keys = frozenset(dirty)
        return DeltaGeneration(views=views, extras=extras, dirty_keys=dirty_keys)

    # --------------------------------------------------------------- write
    def plan_writable(self, *, extra_tokens: int = 1) -> WritePlan:
        """Plan (but do not apply) the page motion the next ``extra_tokens``
        appends need: fresh boundary allocations and CoW privatizations.

        Pages are allocated here (so concurrent planners never collide) but
        nothing else moves — the table, refcounts of existing pages, and
        dirty tracking are untouched until :meth:`PagePool.materialize`
        commits the plan.  On an allocation failure mid-plan, every page
        this plan already took is returned before the error surfaces.
        """
        psz = self.pool.page_size
        fresh: List[Tuple[int, int]] = []
        cow: List[Tuple[int, int, int]] = []
        new_len = self.seq_len + extra_tokens
        first_page = self.seq_len // psz
        last_page = (new_len - 1) // psz
        try:
            for pos in range(first_page, last_page + 1):
                if pos >= len(self.table):
                    raise MemoryError("session exceeded max_pages")
                page = int(self.table[pos])
                needed = pos * psz < new_len
                if not needed:
                    continue
                if pos * psz >= self.seq_len and (page == 0 or self.pool.refs[page] == 0):
                    # fresh page boundary: plain allocation, no copy
                    fresh.append((pos, self.pool.alloc()))
                elif self.pool.refs[page] > 1:
                    # shared page: CoW privatize on commit
                    cow.append((pos, page, self.pool.alloc()))
        except BaseException:
            taken = [pg for _, pg in fresh] + [d for _, _, d in cow]
            if taken:
                self.pool.decref(np.asarray(taken, np.int64))
            raise
        return WritePlan(
            session=self, fresh=fresh, cow=cow, window=(first_page, last_page)
        )

    def ensure_writable(self, *, warm: bool = False, extra_tokens: int = 1) -> int:
        """Guarantee the next ``extra_tokens`` appends hit exclusively-owned
        pages.  Returns the number of CoW copies performed.

        This is the CoW fault (inline) or its async-warm pre-payment; the
        batched step path plans every session first and commits them through
        one :meth:`PagePool.materialize` call instead.
        """
        plan = self.plan_writable(extra_tokens=extra_tokens)
        self.pool.materialize([plan], warm=warm)
        return len(plan.cow)

    def resident_bytes(self) -> int:
        """Footprint attributable to this session (shared pages amortized)."""
        psz_bytes = 0
        for skey, tag in self.pool.attn_tags:
            p = self.pool.pools_k[skey][tag]
            psz_bytes += int(np.prod(p.shape[2:])) * p.dtype.itemsize * 2 * p.shape[0]
        total = 0.0
        for pos in range(self.n_pages):
            page = int(self.table[pos])
            if page:
                total += psz_bytes / max(int(self.pool.refs[page]), 1)
        return int(total)
