"""Serving engine: prefill + batched decode over forkable paged sessions.

The engine owns the model params and the page pool, and exposes the three
operations the agent sandbox needs:

* ``new_session(prompt)`` — prefill a prompt into freshly allocated pages.
* ``step(sessions)``      — one batched decode step: host-side CoW
  preparation (``ensure_writable``), stacked paged decode, per-session
  sampling.  Sessions in the batch may be arbitrary forks of each other —
  the pool's refcounts make sharing safe.
* ``logprobs`` / greedy & temperature sampling with *checkpointable* RNG
  (seed+counter live in session extras, so a restored session replays the
  identical token stream — rollback determinism, §2.2).

Recurrent architectures (mamba/xlstm sublayers) carry their states in
``session.extras`` as immutable jnp arrays: fork is aliasing, restore is
rebinding — the degenerate-but-fastest DeltaCR case (DESIGN.md).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import Model
from .kvcache import PagePool, PagedSession

__all__ = ["Engine", "SamplingParams"]


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0        # 0 = greedy
    seed: int = 0


class Engine:
    def __init__(
        self,
        model: Model,
        params: Any,
        pool: PagePool,
    ):
        self.model = model
        self.params = params
        self.pool = pool
        self.cfg = model.cfg
        self._decode_jit: Dict[int, Any] = {}
        self._prefill_jit: Dict[int, Any] = {}
        self.decode_steps = 0

    # ------------------------------------------------------------ sessions
    def new_session(
        self,
        prompt_tokens: Sequence[int],
        sampling: SamplingParams = SamplingParams(),
    ) -> PagedSession:
        sess = PagedSession(self.pool)
        sess.extras["rng_seed"] = np.asarray([sampling.seed], np.int64)
        sess.extras["rng_counter"] = np.asarray([0], np.int64)
        sess.extras["temperature"] = np.asarray([sampling.temperature], np.float32)
        prompt = list(int(t) for t in prompt_tokens)
        sess.tokens = list(prompt)
        S = len(prompt)
        with self.pool.lock:
            sess.ensure_writable(extra_tokens=S)
            sess.seq_len = S

            cache = self._build_cache([sess], init_recurrent=True)
            tokens = jnp.asarray([prompt], jnp.int32)
            prefill = self._get_prefill(S)
            logits, new_cache = prefill(self.params, tokens, cache)
            self._absorb_cache([sess], new_cache)
        logits_np = np.asarray(logits[0], np.float32)
        sess.extras["last_logits"] = logits_np
        sess.extras["prompt_len"] = np.asarray([S], np.int64)
        # The first generated token comes from the prefill logits; it is
        # appended as the *pending* token (K/V not yet written — the next
        # step writes it at position seq_len).
        sess.tokens.append(self._sample(sess, logits_np))
        return sess

    # ----------------------------------------------------------- decoding
    def step(self, sessions: Sequence[PagedSession]) -> List[int]:
        """One decode step for every session; returns the sampled tokens.

        Each session's ``tokens[-1]`` is its *pending* token (sampled but not
        yet in the cache); the step commits its K/V at position ``seq_len``
        and samples the next pending token.
        """
        # 1. host-side CoW preparation, batched: every session's page motion
        # is planned first, then committed through ONE transactional
        # materialize call — one stacked-kernel launch per layer tag for the
        # whole batch, and a failure (injected fault, allocator, verify)
        # rolls every plan back before any decode math runs
        # the whole step holds the pool lock: the async-warm worker commits
        # materializations into the same pool arrays this step functionally
        # updates, and an interleaved commit would be silently overwritten
        with self.pool.lock:
            plans: List[Any] = []
            try:
                for s in sessions:
                    plans.append(s.plan_writable(extra_tokens=1))
            except BaseException:
                self.pool.discard_plans(plans)
                raise
            self.pool.materialize(plans)
            # 2. stacked decode
            last = [s.tokens[-1] for s in sessions]
            cache = self._build_cache(sessions)
            tokens = jnp.asarray(last, jnp.int32)
            decode = self._get_decode(len(sessions))
            logits, new_cache = decode(self.params, tokens, cache)
            self._absorb_cache(sessions, new_cache, advance=True)
        # 3. sampling with checkpointable rng
        out = []
        logits_np = np.asarray(logits, np.float32)
        for i, s in enumerate(sessions):
            tok = self._sample(s, logits_np[i])
            s.tokens.append(tok)
            s.extras["last_logits"] = logits_np[i]
            out.append(tok)
        self.decode_steps += 1
        return out

    def generate(self, session: PagedSession, n_tokens: int) -> List[int]:
        """Return the first ``n_tokens`` generated after the prompt, stepping
        as needed (the first one was already sampled at prefill)."""
        plen = int(session.extras["prompt_len"][0])
        while len(session.tokens) < plen + n_tokens:
            self.step([session])
        return [int(t) for t in session.tokens[plen : plen + n_tokens]]

    # ----------------------------------------------------------- internals
    def _sample(self, sess: PagedSession, logits: np.ndarray) -> int:
        temp = float(sess.extras["temperature"][0])
        if temp <= 0.0:
            return int(np.argmax(logits))
        seed = int(sess.extras["rng_seed"][0])
        counter = int(sess.extras["rng_counter"][0])
        rng = np.random.default_rng((seed, counter))
        z = logits / temp
        z = z - z.max()
        p = np.exp(z) / np.sum(np.exp(z))
        tok = int(rng.choice(len(p), p=p))
        sess.extras["rng_counter"] = np.asarray([counter + 1], np.int64)
        return tok

    def _build_cache(self, sessions: Sequence[PagedSession], *, init_recurrent: bool = False):
        """Assemble the stacked cache pytree for a batch of sessions."""
        cfg = self.cfg
        B = len(sessions)
        cache: Dict[str, Any] = {
            "lens": jnp.asarray([s.seq_len for s in sessions], jnp.int32)
        }
        tables = jnp.asarray(np.stack([s.table for s in sessions]), jnp.int32)
        for i, stage in enumerate(cfg.stages):
            entries: Dict[str, Any] = {}
            N = stage.n_periods
            for li, layer in enumerate(stage.period):
                for si, kind in enumerate(layer):
                    tag = f"l{li}_s{si}_{kind}"
                    if kind in ("attn", "attn_local"):
                        entries[tag] = {
                            "pk": self.pool.pools_k[f"stage{i}"][tag],
                            "pv": self.pool.pools_v[f"stage{i}"][tag],
                            "table": jnp.broadcast_to(tables[None], (N,) + tables.shape),
                        }
                    elif kind in ("mamba", "mlstm", "slstm"):
                        from repro.models.model import _init_cache_entry

                        if init_recurrent:
                            proto = _init_cache_entry(kind, cfg, B, 1)
                            entries[tag] = jax.tree.map(
                                lambda a: jnp.broadcast_to(a[None], (N,) + a.shape), proto
                            )
                        else:
                            key = f"stage{i}/{tag}"
                            per = [s.extras[key] for s in sessions]  # each (N, 1, ...)
                            entries[tag] = jax.tree.map(
                                lambda *xs: jnp.concatenate(xs, axis=1), *per
                            )
            cache[f"stage{i}"] = entries
        return cache

    def _absorb_cache(self, sessions, new_cache, *, advance: bool = False) -> None:
        """Write updated pools back and split recurrent states per session."""
        cfg = self.cfg
        for i, stage in enumerate(cfg.stages):
            entries = new_cache[f"stage{i}"]
            for li, layer in enumerate(stage.period):
                for si, kind in enumerate(layer):
                    tag = f"l{li}_s{si}_{kind}"
                    if kind in ("attn", "attn_local"):
                        self.pool.pools_k[f"stage{i}"][tag] = entries[tag]["pk"]
                        self.pool.pools_v[f"stage{i}"][tag] = entries[tag]["pv"]
                    elif kind in ("mamba", "mlstm", "slstm"):
                        key = f"stage{i}/{tag}"
                        for b, s in enumerate(sessions):
                            s.extras[key] = jax.tree.map(
                                lambda a: a[:, b : b + 1], entries[tag]
                            )
        if advance:
            for s in sessions:
                s.seq_len += 1

    def _get_decode(self, batch: int):
        if batch not in self._decode_jit:
            self._decode_jit[batch] = jax.jit(self.model.decode_step)
        return self._decode_jit[batch]

    def _get_prefill(self, seq: int):
        if seq not in self._prefill_jit:
            self._prefill_jit[seq] = jax.jit(self.model.prefill)
        return self._prefill_jit[seq]
