"""Continuous-batching scheduler over forkable sessions.

Production serving runs many concurrent agent sessions with different
lifecycles (prefill, decode, suspended-awaiting-tool, finished).  The
scheduler admits sessions up to a page-budget watermark, batches all
decode-ready sessions per step, and — the DeltaBox twist — *suspends*
sessions by checkpointing them through DeltaCR and releasing their pages,
resuming them later via template fork or dump restore.  Suspension turns
idle agents (seconds-long tool calls, human turns) into near-zero HBM
footprint, which is exactly the paper's economics applied to a fleet.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Tuple

from repro.core.deltacr import DeltaCR

from .engine import Engine, SamplingParams
from .kvcache import PagedSession

__all__ = ["Scheduler", "SchedulerConfig", "SessionHandle"]


@dataclasses.dataclass
class SchedulerConfig:
    max_batch: int = 8                   # decode batch per step
    min_free_pages: int = 8              # admission watermark
    auto_suspend_free_pages: int = 4     # suspend LRU sessions below this


@dataclasses.dataclass
class SessionHandle:
    sid: int
    state: str                           # "active" | "suspended" | "finished"
    session: Optional[PagedSession]
    ckpt_id: Optional[int] = None        # set while suspended
    last_step: int = 0


class Scheduler:
    def __init__(self, engine: Engine, deltacr: DeltaCR, cfg: SchedulerConfig = SchedulerConfig()):
        self.engine = engine
        self.cr = deltacr
        self.cfg = cfg
        self.handles: Dict[int, SessionHandle] = {}
        self._sid = itertools.count(1)
        self._ckpt = itertools.count(1_000_000)
        self.step_count = 0
        self.suspensions = 0
        self.resumes = 0

    # --------------------------------------------------------------- admit
    def submit(self, prompt, sampling: SamplingParams = SamplingParams()) -> int:
        """Admit a new session (prefill) if the pool allows; else raise."""
        self._ensure_headroom()
        if self.engine.pool.free_pages() < self.cfg.min_free_pages:
            raise MemoryError("no page headroom for admission")
        sess = self.engine.new_session(list(prompt), sampling)
        sid = next(self._sid)
        self.handles[sid] = SessionHandle(sid=sid, state="active", session=sess)
        return sid

    def fork(self, sid: int) -> int:
        """Fork an active session into a new scheduled session (BoN/search)."""
        h = self.handles[sid]
        assert h.state == "active" and h.session is not None
        child = h.session.fork()
        nsid = next(self._sid)
        self.handles[nsid] = SessionHandle(sid=nsid, state="active", session=child)
        return nsid

    # --------------------------------------------------------------- states
    def suspend(self, sid: int, *, keep_template: bool = False) -> None:
        """Checkpoint through DeltaCR and release the session's pages.

        With ``keep_template=False`` (default) the template is evicted once
        the durable dump lands, so the pages really return to the pool —
        resume then takes the slow path: suspension trades restore latency
        for HBM, exactly the paper's eviction economics."""
        h = self.handles[sid]
        if h.state != "active":
            return
        ckpt_id = next(self._ckpt)
        self.cr.checkpoint(h.session, ckpt_id, None)
        h.session.release()
        if not keep_template:
            fut = self.cr.dump_future(ckpt_id)
            if fut is not None:
                fut.result(timeout=120.0)      # durable image before eviction
            self.cr.evict_template(ckpt_id)
        h.session = None
        h.ckpt_id = ckpt_id
        h.state = "suspended"
        self.suspensions += 1

    def resume(self, sid: int) -> None:
        h = self.handles[sid]
        if h.state != "suspended":
            return
        self._ensure_headroom()
        state, path = self.cr.restore(h.ckpt_id)
        h.session = state
        h.state = "active"
        h.ckpt_id = None
        self.resumes += 1

    def finish(self, sid: int) -> List[int]:
        h = self.handles[sid]
        tokens = list(h.session.tokens) if h.session else []
        if h.session is not None:
            h.session.release()
            h.session = None
        if h.ckpt_id is not None:
            self.cr.drop_checkpoint(h.ckpt_id)
            h.ckpt_id = None
        h.state = "finished"
        return tokens

    # ----------------------------------------------------------------- step
    def step(self) -> Dict[int, int]:
        """One continuous-batching step over decode-ready sessions.

        Returns {sid: sampled token}."""
        ready = [h for h in self.handles.values() if h.state == "active"][: self.cfg.max_batch]
        if not ready:
            return {}
        toks = self.engine.step([h.session for h in ready])
        out = {}
        for h, t in zip(ready, toks):
            h.last_step = self.step_count
            out[h.sid] = t
        self.step_count += 1
        return out

    # ------------------------------------------------------------- internal
    def _ensure_headroom(self) -> None:
        """Below the watermark, suspend LRU active sessions (their templates
        stay forkable; pages return to the pool)."""
        while (
            self.engine.pool.free_pages() < self.cfg.auto_suspend_free_pages
        ):
            actives = [h for h in self.handles.values() if h.state == "active"]
            if len(actives) <= 1:
                break
            lru = min(actives, key=lambda h: h.last_step)
            self.suspend(lru.sid)
