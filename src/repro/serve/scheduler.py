"""Continuous-batching scheduler over forkable sessions, with dump QoS.

Production serving runs many concurrent agent sessions with different
lifecycles (prefill, decode, suspended-awaiting-tool, finished).  The
scheduler admits sessions up to a page-budget watermark, batches all
decode-ready sessions per step, and — the DeltaBox twist — *suspends*
sessions by checkpointing them through DeltaCR and releasing their pages,
resuming them later via template fork or dump restore.  Suspension turns
idle agents (seconds-long tool calls, human turns) into near-zero HBM
footprint, which is exactly the paper's economics applied to a fleet.

Forked children are first-class sessions: ``fork`` splits an active
scheduled session in place, and ``admit_forked`` adopts a session forked
*outside* the scheduler — e.g. a SandboxTree child's process state — into
the same lifecycle (continuous batching, LRU suspension through DeltaCR,
dump QoS), so a search fan-out and the serving fleet share one admission
and eviction policy.

Dump QoS (this layer owns the policy, ``core.stream`` owns the mechanism):

* The scheduler installs a :class:`~repro.core.stream.DumpGate` on DeltaCR's
  streaming engine and flips ``set_runnable`` every step, so background dump
  windows are *demoted* (bounded wait) whenever decode work is ready —
  checkpoint traffic never head-of-line-blocks token generation.
* The gate also bounds in-flight dump windows, so a suspend storm (a search
  fan-out parking dozens of sessions at once) holds at most
  ``max_inflight_dump_windows`` windows of staging memory.
* **Suspend coalescing**: ``suspend`` no longer blocks on the durable dump
  before evicting the template.  Evictions are queued and drained
  opportunistically as dumps land (``step``/``submit``), or forcibly only
  when admission actually needs the pages back — a storm of suspends costs
  one FIFO dump queue, not a chain of synchronous waits.
"""
from __future__ import annotations

import dataclasses
import itertools
from concurrent.futures import Future
from concurrent.futures import TimeoutError as FuturesTimeoutError
from typing import Dict, List, Optional, Tuple

from repro.core.deltacr import DeltaCR
from repro.core.persist import PersistencePlane
from repro.core.stream import DumpGate

from .engine import Engine, SamplingParams
from .kvcache import PagedSession

__all__ = ["Scheduler", "SchedulerConfig", "SessionHandle"]


@dataclasses.dataclass
class SchedulerConfig:
    max_batch: int = 8                   # decode batch per step
    min_free_pages: int = 8              # admission watermark
    auto_suspend_free_pages: int = 4     # suspend LRU sessions below this
    # -- dump QoS --------------------------------------------------------
    dump_qos: bool = True                # install a DumpGate on DeltaCR
    max_inflight_dump_windows: int = 3   # staging bound for dump streams
    dump_demote_poll_ms: float = 2.0     # demoted-window re-check cadence
    dump_demote_max_ms: float = 50.0     # demotion is bounded: dumps progress
    coalesce_suspends: bool = True       # defer template eviction off suspend()
    # -- dump timeout policy ---------------------------------------------
    # How long a synchronous (urgent/uncoalesced) suspend waits for the
    # durable dump, and what a timeout does:
    #   "defer" — count it and queue a deferred eviction; the template stays
    #             live and the pages return when the dump finally lands
    #             (never silently evict a template whose dump didn't land)
    #   "raise" — count it and re-raise to the caller (strict deployments)
    dump_timeout_s: float = 120.0
    dump_timeout_policy: str = "defer"   # "defer" | "raise"
    # -- persistence plane -----------------------------------------------
    # When set, the scheduler commits a crash-consistent manifest snapshot
    # (suspended-session map + DeltaCR image store) every time a coalesced
    # suspend drain lands dumps — a warm pool of parked agents survives
    # process death and is re-admitted via Scheduler.recover().
    persist_path: Optional[str] = None
    keep_snapshots: int = 4


@dataclasses.dataclass
class SessionHandle:
    sid: int
    state: str                           # "active" | "suspended" | "finished"
    session: Optional[PagedSession]
    ckpt_id: Optional[int] = None        # set while suspended
    last_step: int = 0


class Scheduler:
    def __init__(self, engine: Engine, deltacr: DeltaCR, cfg: Optional[SchedulerConfig] = None):
        self.engine = engine
        self.cr = deltacr
        # per-instance config: a shared default instance would alias mutable
        # scheduler tuning across every Scheduler in the process
        self.cfg = cfg if cfg is not None else SchedulerConfig()
        self.handles: Dict[int, SessionHandle] = {}
        self._sid = itertools.count(1)
        self._ckpt = itertools.count(1_000_000)
        if self.cfg.dump_timeout_policy not in ("defer", "raise"):
            raise ValueError(
                f"unknown dump_timeout_policy {self.cfg.dump_timeout_policy!r}"
            )
        self.step_count = 0
        self.suspensions = 0
        self.resumes = 0
        # fault-domain accounting (every timeout/failure is counted, never
        # swallowed silently)
        self.dump_timeouts = 0           # dumps that missed dump_timeout_s
        self.dump_failures = 0           # dumps that failed (template kept)
        # (ckpt_id, dump future) pairs awaiting deferred template eviction
        self._pending_evict: List[Tuple[int, Optional[Future]]] = []
        self.gate: Optional[DumpGate] = None
        if self.cfg.dump_qos:
            self.gate = DumpGate(
                self.cfg.max_inflight_dump_windows,
                demote_poll_ms=self.cfg.dump_demote_poll_ms,
                demote_max_ms=self.cfg.dump_demote_max_ms,
            )
            self.cr.attach_dump_gate(self.gate)
        self.plane: Optional[PersistencePlane] = None
        if self.cfg.persist_path is not None:
            self.plane = PersistencePlane(
                self.cfg.persist_path, keep_snapshots=self.cfg.keep_snapshots
            )

    # --------------------------------------------------------------- admit
    def submit(self, prompt, sampling: Optional[SamplingParams] = None) -> int:
        """Admit a new session (prefill) if the pool allows; else raise."""
        self._drain_suspends()
        self._ensure_headroom()
        if self.engine.pool.free_pages() < self.cfg.min_free_pages:
            raise MemoryError("no page headroom for admission")
        sess = self.engine.new_session(
            list(prompt), sampling if sampling is not None else SamplingParams()
        )
        sid = next(self._sid)
        self.handles[sid] = SessionHandle(sid=sid, state="active", session=sess)
        return sid

    def fork(self, sid: int) -> int:
        """Fork an active session into a new scheduled session (BoN/search)."""
        h = self.handles[sid]
        assert h.state == "active" and h.session is not None
        child = h.session.fork()
        nsid = next(self._sid)
        self.handles[nsid] = SessionHandle(sid=nsid, state="active", session=child)
        self._refresh_runnable_hint()
        return nsid

    def admit_forked(self, session) -> int:
        """Admit an externally forked live session as a scheduled session.

        The SandboxTree integration point: a child forked from a checkpoint
        (its process state is a ``PagedSession``/``ForkableState`` the
        caller owns) joins continuous batching, LRU suspension, and dump
        QoS exactly like a session this scheduler prefilled itself.  The
        scheduler takes ownership: ``finish``/``suspend`` release it.
        Raises ``MemoryError`` when the pool lacks admission headroom (the
        fork itself allocated nothing, but decoding it will)."""
        self._drain_suspends()
        self._ensure_headroom()
        if self.engine.pool.free_pages() < self.cfg.min_free_pages:
            raise MemoryError("no page headroom to admit forked session")
        sid = next(self._sid)
        self.handles[sid] = SessionHandle(sid=sid, state="active", session=session)
        self._refresh_runnable_hint()
        return sid

    # --------------------------------------------------------------- states
    def suspend(self, sid: int, *, keep_template: bool = False, urgent: bool = False) -> None:
        """Checkpoint through DeltaCR and release the session's pages.

        With ``keep_template=False`` (default) the template is evicted once
        the durable dump lands, so the pages really return to the pool —
        resume then takes the slow path: suspension trades restore latency
        for HBM, exactly the paper's eviction economics.

        Coalescing (default): the eviction is *deferred* — queued behind the
        dump future and drained when the dump completes, so a burst of
        suspends (search fan-out, tool-call storm) submits every dump to the
        FIFO worker immediately instead of serializing suspend→wait→suspend.
        ``urgent=True`` restores the old synchronous behavior (pages are
        free when this returns) and marks the dump foreground-priority so
        the QoS gate does not demote its windows.
        """
        h = self.handles[sid]
        if h.state != "active":
            return
        ckpt_id = next(self._ckpt)
        self.cr.checkpoint(h.session, ckpt_id, None, priority="fg" if urgent else "bg")
        # the handle flips to suspended BEFORE any durability wait: the
        # template is live from here on, so even a failed/slow dump leaves a
        # restorable handle — never an "active" one holding a released session
        h.session.release()
        h.session = None
        h.ckpt_id = ckpt_id
        h.state = "suspended"
        self.suspensions += 1
        self._refresh_runnable_hint()
        if not keep_template:
            fut = self.cr.dump_future(ckpt_id)
            if urgent or not self.cfg.coalesce_suspends:
                if fut is not None:
                    try:
                        # durable image before eviction
                        fut.result(timeout=self.cfg.dump_timeout_s)
                    except FuturesTimeoutError:
                        # slow, not failed — routed through the timeout
                        # policy, counted, and the template is NEVER evicted
                        # before its dump lands
                        self.dump_timeouts += 1
                        self._pending_evict.append((ckpt_id, fut))
                        if self.cfg.dump_timeout_policy == "raise":
                            raise
                        return
                    except Exception:
                        # dump failed loudly (ticket aborted): the template
                        # is the only remaining copy of the state — keep it
                        self.dump_failures += 1
                        return
                self.cr.evict_template(ckpt_id)
                self.cr.release_dump_anchor(ckpt_id)  # really return the pages
            else:
                self._pending_evict.append((ckpt_id, fut))

    def suspend_many(self, sids, **kw) -> None:
        """Suspend a burst of sessions; with coalescing on, all dumps queue
        on the FIFO worker before any eviction wait happens."""
        for sid in sids:
            self.suspend(sid, **kw)

    def resume(self, sid: int) -> None:
        h = self.handles[sid]
        if h.state != "suspended":
            return
        self._drain_suspends()
        self._ensure_headroom()
        state, path = self.cr.restore(h.ckpt_id)
        h.session = state
        h.state = "active"
        h.ckpt_id = None
        self.resumes += 1
        self._refresh_runnable_hint()

    def finish(self, sid: int) -> List[int]:
        h = self.handles[sid]
        tokens = list(h.session.tokens) if h.session else []
        if h.session is not None:
            h.session.release()
            h.session = None
        if h.ckpt_id is not None:
            self._pending_evict = [
                (c, f) for c, f in self._pending_evict if c != h.ckpt_id
            ]
            self.cr.drop_checkpoint(h.ckpt_id)
            h.ckpt_id = None
        h.state = "finished"
        self._refresh_runnable_hint()
        return tokens

    # ----------------------------------------------------------------- step
    def step(self) -> Dict[int, int]:
        """One continuous-batching step over decode-ready sessions.

        Returns {sid: sampled token}."""
        self._drain_suspends()
        ready = [h for h in self.handles.values() if h.state == "active"][: self.cfg.max_batch]
        if self.gate is not None:
            # QoS hint: while these sessions decode, background dump windows
            # are demoted; cleared when the scheduler runs dry
            self.gate.set_runnable(len(ready))
        if not ready:
            return {}
        toks = self.engine.step([h.session for h in ready])
        out = {}
        for h, t in zip(ready, toks):
            h.last_step = self.step_count
            out[h.sid] = t
        self.step_count += 1
        return out

    # ---------------------------------------------------------------- health
    def health(self) -> Dict[str, object]:
        """One fault-domain snapshot across the stack this scheduler drives:
        DeltaCR's retry/fallback/degraded counters and verified-read repair
        stats, dump-worker supervision, drain-pool restarts, the QoS gate,
        and this scheduler's own timeout/failure counts.  Cheap to poll —
        no locks beyond the stats locks."""
        h: Dict[str, object] = dict(self.cr.health())
        h["scheduler_dump_timeouts"] = self.dump_timeouts
        h["scheduler_dump_failures"] = self.dump_failures
        h["pending_evictions"] = len(self._pending_evict)
        h["suspensions"] = self.suspensions
        h["resumes"] = self.resumes
        h["sessions_active"] = sum(
            1 for x in self.handles.values() if x.state == "active"
        )
        h["sessions_suspended"] = sum(
            1 for x in self.handles.values() if x.state == "suspended"
        )
        if self.gate is not None:
            h["gate_acquires"] = self.gate.stats.acquires
            h["gate_demotions"] = self.gate.stats.demotions
        # a single boolean for monitors: anything degraded/broken right now?
        h["ok"] = (
            not h.get("degraded", False)
            and int(h.get("quarantined_chunks", 0)) == 0
            and self.dump_failures == 0
            and int(h.get("dump_failures", 0)) == 0
        )
        return h

    # ------------------------------------------------------------- internal
    def _refresh_runnable_hint(self) -> None:
        """Keep the QoS gate's runnable count honest on state transitions.

        step() sets the authoritative per-batch count; this catches the
        in-between case — a suspend storm parking every active session must
        *promote* the queued dumps immediately, not leave them demoted
        against decode work that no longer exists."""
        if self.gate is not None:
            n = sum(1 for h in self.handles.values() if h.state == "active")
            self.gate.set_runnable(min(n, self.cfg.max_batch))

    def _drain_suspends(self, *, block: bool = False) -> int:
        """Evict templates whose dumps have landed (deferred suspensions).

        ``block=True`` additionally waits on the *oldest* pending dump — the
        bounded backpressure admission applies when it really needs pages.
        Returns the number of templates evicted."""
        if not self._pending_evict:
            return 0
        evicted = 0
        remaining: List[Tuple[int, Optional[Future]]] = []
        for i, (ckpt_id, fut) in enumerate(self._pending_evict):
            wait = block and i == 0
            if fut is None or fut.done() or wait:
                if fut is not None:
                    try:
                        fut.result(timeout=self.cfg.dump_timeout_s)
                    except FuturesTimeoutError:
                        # slow, not failed: counted, and the entry is kept so
                        # the eviction (and its pages) still happens when the
                        # dump lands — the template outlives its dump, always
                        self.dump_timeouts += 1
                        remaining.append((ckpt_id, fut))
                        if self.cfg.dump_timeout_policy == "raise":
                            self._pending_evict = remaining + self._pending_evict[i + 1 :]
                            raise
                        continue
                    except Exception:
                        # dump failed loudly: counted; keep the template (the
                        # only remaining copy of the state) — pages stay
                        # held, state stays safe
                        self.dump_failures += 1
                        continue
                self.cr.evict_template(ckpt_id)
                self.cr.release_dump_anchor(ckpt_id)   # really return the pages
                evicted += 1
            else:
                remaining.append((ckpt_id, fut))
        self._pending_evict = remaining
        if evicted and self.plane is not None:
            # the just-landed dumps are durable in the image store; commit
            # the manifest so this warm pool survives process death
            self.persist_now()
        return evicted

    # ---------------------------------------------------------- persistence
    def persist_now(self) -> Optional[int]:
        """Commit a manifest snapshot of the suspended warm pool (sessions
        whose dumps have landed + the DeltaCR image store); returns the
        snapshot seq, or None when no plane is configured."""
        if self.plane is None:
            return None
        sessions = sorted(
            (h.sid, h.ckpt_id)
            for h in self.handles.values()
            if h.state == "suspended"
            and h.ckpt_id is not None
            and self.cr.images.image_for(h.ckpt_id) is not None
        )
        return self.plane.save(
            deltacr=self.cr,
            extra={"sessions": [list(s) for s in sessions]},
        )

    @classmethod
    def recover(
        cls,
        engine: Engine,
        path: str,
        cfg: Optional[SchedulerConfig] = None,
        *,
        restore_fn,
    ) -> "Scheduler":
        """Rebuild a scheduler warm pool after process death.

        Recovers the persisted DeltaCR image store and re-admits every
        persisted suspended session as a ``suspended`` handle; ``resume``
        then slow-restores it from its durable image exactly as if this
        process had suspended it.  ``restore_fn`` rebuilds a session from
        an image payload (e.g. ``PagedSession.restore_from_payload``)."""
        from repro.core.persist import recover as recover_state

        rec = recover_state(path, restore_fn=restore_fn)
        cfg = cfg if cfg is not None else SchedulerConfig()
        if cfg.persist_path is None:
            cfg = dataclasses.replace(cfg, persist_path=path)
        sched = cls(engine, rec.deltacr, cfg)
        max_sid, max_ckpt = 0, 1_000_000 - 1
        for sid, ckpt_id in rec.extra.get("sessions", []):
            sid, ckpt_id = int(sid), int(ckpt_id)
            sched.handles[sid] = SessionHandle(
                sid=sid, state="suspended", session=None, ckpt_id=ckpt_id
            )
            max_sid = max(max_sid, sid)
            max_ckpt = max(max_ckpt, ckpt_id)
        sched._sid = itertools.count(max_sid + 1)
        sched._ckpt = itertools.count(max_ckpt + 1)
        return sched

    def _ensure_headroom(self) -> None:
        """Below the watermark: first reap deferred evictions, then suspend
        LRU active sessions, and only block on a pending dump when nothing
        else can yield pages."""
        while self.engine.pool.free_pages() < self.cfg.auto_suspend_free_pages:
            if self._drain_suspends():
                continue
            if self._pending_evict and self._drain_suspends(block=True):
                continue                 # a queued dump landed: pages are back
            actives = [h for h in self.handles.values() if h.state == "active"]
            if len(actives) <= 1:
                break
            lru = min(actives, key=lambda h: h.last_step)
            self.suspend(lru.sid)
