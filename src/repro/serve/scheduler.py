"""Continuous-batching scheduler over forkable sessions, with dump QoS.

Production serving runs many concurrent agent sessions with different
lifecycles (prefill, decode, suspended-awaiting-tool, finished).  The
scheduler admits sessions up to a page-budget watermark, batches all
decode-ready sessions per step, and — the DeltaBox twist — *suspends*
sessions by checkpointing them through DeltaCR and releasing their pages,
resuming them later via template fork or dump restore.  Suspension turns
idle agents (seconds-long tool calls, human turns) into near-zero HBM
footprint, which is exactly the paper's economics applied to a fleet.

Forked children are first-class sessions: ``fork`` splits an active
scheduled session in place, and ``admit_forked`` adopts a session forked
*outside* the scheduler — e.g. a SandboxTree child's process state — into
the same lifecycle (continuous batching, LRU suspension through DeltaCR,
dump QoS), so a search fan-out and the serving fleet share one admission
and eviction policy.

Dump QoS (this layer owns the policy, ``core.stream`` owns the mechanism):

* The scheduler installs a :class:`~repro.core.stream.DumpGate` on DeltaCR's
  streaming engine and flips ``set_runnable`` every step, so background dump
  windows are *demoted* (bounded wait) whenever decode work is ready —
  checkpoint traffic never head-of-line-blocks token generation.
* The gate also bounds in-flight dump windows, so a suspend storm (a search
  fan-out parking dozens of sessions at once) holds at most
  ``max_inflight_dump_windows`` windows of staging memory.
* **Suspend coalescing**: ``suspend`` no longer blocks on the durable dump
  before evicting the template.  Evictions are queued and drained
  opportunistically as dumps land (``step``/``submit``), or forcibly only
  when admission actually needs the pages back — a storm of suspends costs
  one FIFO dump queue, not a chain of synchronous waits.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from concurrent.futures import Future
from concurrent.futures import TimeoutError as FuturesTimeoutError
from typing import Dict, List, Optional, Tuple

from repro.core.deltacr import DeltaCR
from repro.core.persist import PersistencePlane
from repro.core.policy import DumpPolicy
from repro.core.stream import DumpGate

from .engine import Engine, SamplingParams
from .kvcache import PagedSession

__all__ = ["Scheduler", "SchedulerConfig", "SessionHandle"]


@dataclasses.dataclass
class SchedulerConfig:
    max_batch: int = 8                   # decode batch per step
    min_free_pages: int = 8              # admission watermark
    auto_suspend_free_pages: int = 4     # suspend LRU sessions below this
    # Batching window: >0 makes ``generate`` wait up to this long before
    # each step for sibling requests to coalesce (early-exit once
    # ``max_batch`` sessions want tokens).  Worth ~a batch-width of decode
    # throughput when concurrent callers arrive staggered (forked MCTS
    # leaves); 0 keeps the latency-first default.
    batch_window_ms: float = 0.0
    # -- dump QoS --------------------------------------------------------
    dump_qos: bool = True                # install a DumpGate on DeltaCR
    max_inflight_dump_windows: int = 3   # staging bound for dump streams
    dump_demote_poll_ms: float = 2.0     # demoted-window re-check cadence
    dump_demote_max_ms: float = 50.0     # demotion is bounded: dumps progress
    coalesce_suspends: bool = True       # defer template eviction off suspend()
    # -- dump timeout policy ---------------------------------------------
    # How long a synchronous (urgent/uncoalesced) suspend waits for the
    # durable dump, and what a timeout does:
    #   "defer" — count it and queue a deferred eviction; the template stays
    #             live and the pages return when the dump finally lands
    #             (never silently evict a template whose dump didn't land)
    #   "raise" — count it and re-raise to the caller (strict deployments)
    dump_timeout_s: float = 120.0
    dump_timeout_policy: str = "defer"   # "defer" | "raise"
    # -- dump policy -----------------------------------------------------
    # When set, the scheduler re-points its DeltaCR at this DumpPolicy on
    # construction (Scheduler owns the dump QoS surface; the selection /
    # retry / deadline / fused knobs ride along the same way).  None keeps
    # whatever policy the DeltaCR was built with.
    dump_policy: Optional[DumpPolicy] = None
    # -- persistence plane -----------------------------------------------
    # When set, the scheduler commits a crash-consistent manifest snapshot
    # (suspended-session map + DeltaCR image store) every time a coalesced
    # suspend drain lands dumps — a warm pool of parked agents survives
    # process death and is re-admitted via Scheduler.recover().
    persist_path: Optional[str] = None
    keep_snapshots: int = 4
    # Full-snapshot anchor cadence: saves in between are O(delta) docs
    # folded onto the last anchor at recovery (1 = every save is full).
    persist_full_every: int = 8
    # When > 0, compact the manifest (fresh full snapshot + history
    # truncation + pack sweep) every this many saves.
    persist_compact_every: int = 0


@dataclasses.dataclass
class SessionHandle:
    sid: int
    state: str                           # "active" | "suspended" | "finished"
    session: Optional[PagedSession]
    ckpt_id: Optional[int] = None        # set while suspended
    last_step: int = 0
    # -- decode-service request state (continuous batching) ---------------
    want: int = 0                        # outstanding requested decode tokens
    got: List[int] = dataclasses.field(default_factory=list)
    waiter: Optional[Future] = None      # resolves with ``got`` when want==0


class Scheduler:
    def __init__(self, engine: Engine, deltacr: DeltaCR, cfg: Optional[SchedulerConfig] = None):
        self.engine = engine
        self.cr = deltacr
        # per-instance config: a shared default instance would alias mutable
        # scheduler tuning across every Scheduler in the process
        self.cfg = cfg if cfg is not None else SchedulerConfig()
        self.handles: Dict[int, SessionHandle] = {}
        self._sid = itertools.count(1)
        self._ckpt = itertools.count(1_000_000)
        # Handle-table + pool-mutation lock: forked MCTS workers call
        # admit_forked/generate/detach from their own threads while a step
        # decodes, and slow restores scatter into the same pool arrays the
        # step functionally updates — every public mutator serializes here.
        self._lock = threading.RLock()
        # Decode service: at most one thread runs engine.step at a time;
        # whichever generate() caller grabs this lock services every
        # waiting request (continuous batching by thread-stealing)
        self._step_lock = threading.Lock()
        if self.cfg.dump_timeout_policy not in ("defer", "raise"):
            raise ValueError(
                f"unknown dump_timeout_policy {self.cfg.dump_timeout_policy!r}"
            )
        if self.cfg.dump_policy is not None:
            self.cr.apply_policy(self.cfg.dump_policy)
        self.step_count = 0
        self.suspensions = 0
        self.resumes = 0
        # fault-domain accounting (every timeout/failure is counted, never
        # swallowed silently)
        self.dump_timeouts = 0           # dumps that missed dump_timeout_s
        self.dump_failures = 0           # dumps that failed (template kept)
        # (ckpt_id, dump future) pairs awaiting deferred template eviction
        self._pending_evict: List[Tuple[int, Optional[Future]]] = []
        self.gate: Optional[DumpGate] = None
        if self.cfg.dump_qos:
            self.gate = DumpGate(
                self.cfg.max_inflight_dump_windows,
                demote_poll_ms=self.cfg.dump_demote_poll_ms,
                demote_max_ms=self.cfg.dump_demote_max_ms,
            )
            self.cr.attach_dump_gate(self.gate)
        self.plane: Optional[PersistencePlane] = None
        if self.cfg.persist_path is not None:
            self.plane = PersistencePlane(
                self.cfg.persist_path,
                keep_snapshots=self.cfg.keep_snapshots,
                full_every=self.cfg.persist_full_every,
                compact_every=self.cfg.persist_compact_every,
            )

    # --------------------------------------------------------------- admit
    def submit(self, prompt, sampling: Optional[SamplingParams] = None) -> int:
        """Admit a new session (prefill) if the pool allows; else raise."""
        with self._lock:
            self._drain_suspends()
            self._ensure_headroom()
            if self.engine.pool.free_pages() < self.cfg.min_free_pages:
                raise MemoryError("no page headroom for admission")
            sess = self.engine.new_session(
                list(prompt), sampling if sampling is not None else SamplingParams()
            )
            sid = next(self._sid)
            self.handles[sid] = SessionHandle(sid=sid, state="active", session=sess)
            return sid

    def fork(self, sid: int) -> int:
        """Fork an active session into a new scheduled session (BoN/search)."""
        with self._lock:
            h = self.handles[sid]
            assert h.state == "active" and h.session is not None
            child = h.session.fork()
            nsid = next(self._sid)
            self.handles[nsid] = SessionHandle(sid=nsid, state="active", session=child)
            self._refresh_runnable_hint()
            return nsid

    def admit_forked(self, session) -> int:
        """Admit an externally forked live session as a scheduled session.

        The SandboxTree integration point: a child forked from a checkpoint
        (its process state is a ``PagedSession``/``ForkableState`` the
        caller owns) joins continuous batching, LRU suspension, and dump
        QoS exactly like a session this scheduler prefilled itself.  The
        scheduler takes ownership: ``finish``/``suspend`` release it.
        Raises ``MemoryError`` when the pool lacks admission headroom (the
        fork itself allocated nothing, but decoding it will)."""
        with self._lock:
            self._drain_suspends()
            self._ensure_headroom()
            if self.engine.pool.free_pages() < self.cfg.min_free_pages:
                raise MemoryError("no page headroom to admit forked session")
            sid = next(self._sid)
            self.handles[sid] = SessionHandle(sid=sid, state="active", session=session)
            self._refresh_runnable_hint()
            return sid

    def session(self, sid: int) -> PagedSession:
        """The live session behind a handle (resuming it if parked).

        Suspension/resume changes the session's object identity (checkpoint
        + release, then template fork); callers holding a direct reference
        — a SandboxTree child's ``proc`` — rebind through here."""
        with self._lock:
            h = self.handles[sid]
            if h.state == "suspended":
                self.resume(sid)
            if h.state != "active" or h.session is None:
                raise KeyError(f"session {sid} is not live ({h.state})")
            return h.session

    def detach(self, sid: int) -> PagedSession:
        """Remove a handle and hand its live session back to the caller.

        The inverse of ``admit_forked``: ownership returns to the caller
        (a SandboxTree child's teardown releases the proc itself), so the
        scheduler must NOT release it here.  A handle the scheduler
        auto-suspended in the meantime is resumed first — the caller always
        gets a live session back (its identity may differ from the one
        admitted: suspension is checkpoint + release, resume is a fork)."""
        with self._lock:
            h = self.handles[sid]
            if h.state == "suspended":
                self.resume(sid)
            if h.state != "active" or h.session is None:
                raise KeyError(f"session {sid} is not detachable ({h.state})")
            if h.waiter is not None:
                raise RuntimeError(f"session {sid} detached with a request in flight")
            sess = h.session
            h.session = None
            h.state = "finished"
            del self.handles[sid]
            self._refresh_runnable_hint()
            return sess

    # --------------------------------------------------------------- states
    def suspend(self, sid: int, *, keep_template: bool = False, urgent: bool = False) -> None:
        """Checkpoint through DeltaCR and release the session's pages.

        With ``keep_template=False`` (default) the template is evicted once
        the durable dump lands, so the pages really return to the pool —
        resume then takes the slow path: suspension trades restore latency
        for HBM, exactly the paper's eviction economics.

        Coalescing (default): the eviction is *deferred* — queued behind the
        dump future and drained when the dump completes, so a burst of
        suspends (search fan-out, tool-call storm) submits every dump to the
        FIFO worker immediately instead of serializing suspend→wait→suspend.
        ``urgent=True`` restores the old synchronous behavior (pages are
        free when this returns) and marks the dump foreground-priority so
        the QoS gate does not demote its windows.
        """
        with self._lock:
            self._suspend_locked(sid, keep_template=keep_template, urgent=urgent)

    def _suspend_locked(self, sid: int, *, keep_template: bool, urgent: bool) -> None:
        h = self.handles[sid]
        if h.state != "active":
            return
        if h.waiter is not None:
            # a decode request is in flight on another thread: fail it
            # loudly rather than silently parking a session mid-request
            w, h.waiter = h.waiter, None
            h.want = 0
            w.set_exception(RuntimeError(f"session {sid} suspended mid-request"))
        ckpt_id = next(self._ckpt)
        self.cr.checkpoint(h.session, ckpt_id, None, priority="fg" if urgent else "bg")
        # the handle flips to suspended BEFORE any durability wait: the
        # template is live from here on, so even a failed/slow dump leaves a
        # restorable handle — never an "active" one holding a released session
        h.session.release()
        h.session = None
        h.ckpt_id = ckpt_id
        h.state = "suspended"
        self.suspensions += 1
        self._refresh_runnable_hint()
        if not keep_template:
            fut = self.cr.dump_future(ckpt_id)
            if urgent or not self.cfg.coalesce_suspends:
                if fut is not None:
                    try:
                        # durable image before eviction
                        fut.result(timeout=self.cfg.dump_timeout_s)
                    except FuturesTimeoutError:
                        # slow, not failed — routed through the timeout
                        # policy, counted, and the template is NEVER evicted
                        # before its dump lands
                        self.dump_timeouts += 1
                        self._pending_evict.append((ckpt_id, fut))
                        if self.cfg.dump_timeout_policy == "raise":
                            raise
                        return
                    except Exception:
                        # dump failed loudly (ticket aborted): the template
                        # is the only remaining copy of the state — keep it
                        self.dump_failures += 1
                        return
                self.cr.evict_template(ckpt_id)
                self.cr.release_dump_anchor(ckpt_id)  # really return the pages
            else:
                self._pending_evict.append((ckpt_id, fut))

    def suspend_many(self, sids, **kw) -> None:
        """Suspend a burst of sessions; with coalescing on, all dumps queue
        on the FIFO worker before any eviction wait happens."""
        for sid in sids:
            self.suspend(sid, **kw)

    def resume(self, sid: int) -> None:
        with self._lock:
            h = self.handles[sid]
            if h.state != "suspended":
                return
            self._drain_suspends()
            self._ensure_headroom()
            state, path = self.cr.restore(h.ckpt_id)
            h.session = state
            h.state = "active"
            h.ckpt_id = None
            self.resumes += 1
            self._refresh_runnable_hint()

    def finish(self, sid: int) -> List[int]:
        with self._lock:
            h = self.handles[sid]
            tokens = list(h.session.tokens) if h.session else []
            if h.session is not None:
                h.session.release()
                h.session = None
            if h.ckpt_id is not None:
                self._pending_evict = [
                    (c, f) for c, f in self._pending_evict if c != h.ckpt_id
                ]
                self.cr.drop_checkpoint(h.ckpt_id)
                h.ckpt_id = None
            h.state = "finished"
            self._refresh_runnable_hint()
            return tokens

    # ----------------------------------------------------------------- step
    def step(self) -> Dict[int, int]:
        """One continuous-batching step over decode-ready sessions.

        When decode *requests* are outstanding (``request_tokens``/
        ``generate``), the batch is exactly the requesting sessions — an
        admitted session nobody asked to decode is never stepped out from
        under its owner.  With no requests pending, every active session is
        batched (the fleet-serving default).  Returns {sid: sampled token}.
        """
        with self._lock:
            self._drain_suspends()
            actives = [h for h in self.handles.values() if h.state == "active"]
            wanting = [h for h in actives if h.want > 0]
            ready = (wanting if wanting else actives)[: self.cfg.max_batch]
            if self.gate is not None:
                # QoS hint: while these sessions decode, background dump
                # windows are demoted; cleared when the scheduler runs dry
                self.gate.set_runnable(len(ready))
            if not ready:
                return {}
            try:
                toks = self.engine.step([h.session for h in ready])
            except BaseException as exc:
                # a failed batched step (CoW fault, allocator) aborts every
                # waiting request loudly — refs were already rolled back
                for h in ready:
                    if h.waiter is not None:
                        w, h.waiter = h.waiter, None
                        h.want = 0
                        w.set_exception(
                            exc if isinstance(exc, Exception) else RuntimeError(repr(exc))
                        )
                raise
            out = {}
            for h, t in zip(ready, toks):
                h.last_step = self.step_count
                out[h.sid] = t
                if h.want > 0:
                    h.want -= 1
                    h.got.append(int(t))
                    if h.want == 0 and h.waiter is not None:
                        w, h.waiter = h.waiter, None
                        w.set_result(list(h.got))
            self.step_count += 1
            return out

    # -------------------------------------------------------- decode service
    def request_tokens(self, sid: int, n: int) -> Future:
        """Ask the decode service for ``n`` more tokens from session ``sid``.

        Returns a future resolving to the list of sampled tokens once ``n``
        continuous-batching steps have included the session.  A suspended
        handle is resumed first.  The request is *served* by whoever drives
        ``step()`` — the background fleet loop, or any thread inside
        ``generate`` (work-stealing: one blocked caller steps the shared
        batch for everyone)."""
        with self._lock:
            h = self.handles[sid]
            if h.state == "suspended":
                self.resume(sid)
            if h.state != "active":
                raise KeyError(f"session {sid} is not decodable ({h.state})")
            if h.waiter is not None:
                raise RuntimeError(f"session {sid} already has a request in flight")
            fut: Future = Future()
            h.got = []
            if n <= 0:
                fut.set_result([])
                return fut
            h.want = int(n)
            h.waiter = fut
            return fut

    def generate(self, sid: int, n: int, *, timeout_s: float = 300.0) -> List[int]:
        """Decode ``n`` tokens through the shared continuous-batching loop.

        Safe to call from many threads at once (the parallel-MCTS workers
        do): each caller's request joins the same batch, and exactly one
        caller at a time drives ``step()`` while the rest wait on their
        futures — forked siblings admitted through ``admit_forked`` decode
        together, one stacked kernel launch per step for the whole set."""
        fut = self.request_tokens(sid, n)
        deadline = time.monotonic() + timeout_s
        while not fut.done():
            if self._step_lock.acquire(timeout=0.002):
                try:
                    # Serve until the shared batch runs dry, not merely until
                    # our own request resolves: releasing the lock the moment
                    # our future lands would strand every sibling request in
                    # its wait-timeout (tens of ms each).  The holder drains
                    # all pending wants so siblings' futures resolve the
                    # instant their last token is sampled.
                    while self._pending_wants():
                        self._coalesce_window()
                        self.step()
                finally:
                    self._step_lock.release()
            else:
                # another caller is stepping the shared batch
                try:
                    return list(fut.result(timeout=0.02))
                except FuturesTimeoutError:
                    pass
            if time.monotonic() > deadline:
                raise TimeoutError(f"generate({sid}, {n}) missed {timeout_s}s deadline")
        return list(fut.result())

    def _coalesce_window(self) -> None:
        """Give concurrently-arriving requests ``batch_window_ms`` to join
        the next step's batch (no-op when the window is 0).  Exits early the
        moment ``max_batch`` sessions want tokens — a full batch gains
        nothing by waiting."""
        w_s = self.cfg.batch_window_ms / 1e3
        if w_s <= 0:
            return
        deadline = time.monotonic() + w_s
        while time.monotonic() < deadline:
            with self._lock:
                wanting = sum(
                    1
                    for h in self.handles.values()
                    if h.state == "active" and h.want > 0
                )
            if wanting >= self.cfg.max_batch:
                return
            time.sleep(w_s / 8)

    def _pending_wants(self) -> bool:
        with self._lock:
            return any(
                h.want > 0 for h in self.handles.values() if h.state == "active"
            )

    # ---------------------------------------------------------------- health
    def health(self) -> Dict[str, object]:
        """One fault-domain snapshot across the stack this scheduler drives:
        DeltaCR's retry/fallback/degraded counters and verified-read repair
        stats, dump-worker supervision, drain-pool restarts, the QoS gate,
        and this scheduler's own timeout/failure counts.  Cheap to poll —
        no locks beyond the stats locks."""
        h: Dict[str, object] = dict(self.cr.health())
        h["scheduler_dump_timeouts"] = self.dump_timeouts
        h["scheduler_dump_failures"] = self.dump_failures
        h["pending_evictions"] = len(self._pending_evict)
        h["suspensions"] = self.suspensions
        h["resumes"] = self.resumes
        h["sessions_active"] = sum(
            1 for x in self.handles.values() if x.state == "active"
        )
        h["sessions_suspended"] = sum(
            1 for x in self.handles.values() if x.state == "suspended"
        )
        if self.gate is not None:
            h["gate_acquires"] = self.gate.stats.acquires
            h["gate_demotions"] = self.gate.stats.demotions
        if self.plane is not None:
            h["persist_saves"] = self.plane.saves
            h["persist_compactions"] = self.plane.compactions
            if self.plane.last_save_stats:
                h["persist_last_kind"] = self.plane.last_save_stats.get("kind")
                h["persist_last_bytes"] = self.plane.last_save_stats.get("bytes_written")
        # a single boolean for monitors: anything degraded/broken right now?
        h["ok"] = (
            not h.get("degraded", False)
            and int(h.get("quarantined_chunks", 0)) == 0
            and self.dump_failures == 0
            and int(h.get("dump_failures", 0)) == 0
        )
        return h

    # ------------------------------------------------------------- internal
    def _refresh_runnable_hint(self) -> None:
        """Keep the QoS gate's runnable count honest on state transitions.

        step() sets the authoritative per-batch count; this catches the
        in-between case — a suspend storm parking every active session must
        *promote* the queued dumps immediately, not leave them demoted
        against decode work that no longer exists."""
        if self.gate is not None:
            n = sum(1 for h in self.handles.values() if h.state == "active")
            self.gate.set_runnable(min(n, self.cfg.max_batch))

    def _drain_suspends(self, *, block: bool = False) -> int:
        """Evict templates whose dumps have landed (deferred suspensions).

        ``block=True`` additionally waits on the *oldest* pending dump — the
        bounded backpressure admission applies when it really needs pages.
        Returns the number of templates evicted."""
        if not self._pending_evict:
            return 0
        evicted = 0
        remaining: List[Tuple[int, Optional[Future]]] = []
        for i, (ckpt_id, fut) in enumerate(self._pending_evict):
            wait = block and i == 0
            if fut is None or fut.done() or wait:
                if fut is not None:
                    try:
                        fut.result(timeout=self.cfg.dump_timeout_s)
                    except FuturesTimeoutError:
                        # slow, not failed: counted, and the entry is kept so
                        # the eviction (and its pages) still happens when the
                        # dump lands — the template outlives its dump, always
                        self.dump_timeouts += 1
                        remaining.append((ckpt_id, fut))
                        if self.cfg.dump_timeout_policy == "raise":
                            self._pending_evict = remaining + self._pending_evict[i + 1 :]
                            raise
                        continue
                    except Exception:
                        # dump failed loudly: counted; keep the template (the
                        # only remaining copy of the state) — pages stay
                        # held, state stays safe
                        self.dump_failures += 1
                        continue
                self.cr.evict_template(ckpt_id)
                self.cr.release_dump_anchor(ckpt_id)   # really return the pages
                evicted += 1
            else:
                remaining.append((ckpt_id, fut))
        self._pending_evict = remaining
        if evicted and self.plane is not None:
            # the just-landed dumps are durable in the image store; commit
            # the manifest so this warm pool survives process death
            self.persist_now()
        return evicted

    # ---------------------------------------------------------- persistence
    def persist_now(self) -> Optional[int]:
        """Commit a manifest snapshot of the suspended warm pool (sessions
        whose dumps have landed + the DeltaCR image store); returns the
        snapshot seq, or None when no plane is configured."""
        if self.plane is None:
            return None
        with self._lock:
            sessions = sorted(
                (h.sid, h.ckpt_id)
                for h in self.handles.values()
                if h.state == "suspended"
                and h.ckpt_id is not None
                and self.cr.images.image_for(h.ckpt_id) is not None
            )
        return self.plane.save(
            deltacr=self.cr,
            extra={"sessions": [list(s) for s in sessions]},
        )

    @classmethod
    def recover(
        cls,
        engine: Engine,
        path: str,
        cfg: Optional[SchedulerConfig] = None,
        *,
        restore_fn,
    ) -> "Scheduler":
        """Rebuild a scheduler warm pool after process death.

        Recovers the persisted DeltaCR image store and re-admits every
        persisted suspended session as a ``suspended`` handle; ``resume``
        then slow-restores it from its durable image exactly as if this
        process had suspended it.  ``restore_fn`` rebuilds a session from
        an image payload (e.g. ``PagedSession.restore_from_payload``)."""
        from repro.core.persist import recover as recover_state

        rec = recover_state(path, restore_fn=restore_fn)
        cfg = cfg if cfg is not None else SchedulerConfig()
        if cfg.persist_path is None:
            cfg = dataclasses.replace(cfg, persist_path=path)
        sched = cls(engine, rec.deltacr, cfg)
        max_sid, max_ckpt = 0, 1_000_000 - 1
        for sid, ckpt_id in rec.extra.get("sessions", []):
            sid, ckpt_id = int(sid), int(ckpt_id)
            sched.handles[sid] = SessionHandle(
                sid=sid, state="suspended", session=None, ckpt_id=ckpt_id
            )
            max_sid = max(max_sid, sid)
            max_ckpt = max(max_ckpt, ckpt_id)
        sched._sid = itertools.count(max_sid + 1)
        sched._ckpt = itertools.count(max_ckpt + 1)
        return sched

    def _ensure_headroom(self) -> None:
        """Below the watermark: first reap deferred evictions, then suspend
        LRU active sessions, and only block on a pending dump when nothing
        else can yield pages."""
        while self.engine.pool.free_pages() < self.cfg.auto_suspend_free_pages:
            if self._drain_suspends():
                continue
            if self._pending_evict and self._drain_suspends(block=True):
                continue                 # a queued dump landed: pages are back
            actives = [h for h in self.handles.values() if h.state == "active"]
            if len(actives) <= 1:
                break
            # prefer parking sessions nobody is mid-request on
            idle = [h for h in actives if h.want == 0 and h.waiter is None] or actives
            lru = min(idle, key=lambda h: h.last_step)
            self.suspend(lru.sid)
