"""UCT Monte-Carlo Tree Search over DeltaState checkpoints.

The paper's primary workload (SWE-Search-style MCTS, §2.1/§6.2.1): every
expansion checkpoints at the parent node and rolls back to arbitrary
ancestors, so C/R latency lands on the critical path once per iteration.

The search tree *is* the snapshot index tree: selection walks SnapshotNodes,
expansion = ``restore(parent) → act → checkpoint``, evaluation runs under
``isolated_eval`` (value-time test isolation, §4.3), and the reachability
GC's ``expandable``/``terminal`` flags are maintained here.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Dict, List, Optional, Protocol, Sequence, Tuple

from repro.core import StateManager, Sandbox, reachability_gc

__all__ = ["MCTSConfig", "AgentTask", "MCTS", "MCTSStats"]


class AgentTask(Protocol):
    """The environment an agent explores inside the sandbox."""

    def propose_actions(self, sandbox: Sandbox, rng_seed: int) -> Sequence[Any]:
        """Candidate actions at the current state (the LLM proposal step)."""

    def apply_action(self, sandbox: Sandbox, action: Any) -> None:
        """Execute one action (mutates fs/proc; may call the engine)."""

    def evaluate(self, sandbox: Sandbox) -> float:
        """Value estimate in [0,1]; may have side effects (run under
        isolated_eval)."""

    def is_terminal(self, sandbox: Sandbox) -> bool: ...

    def is_readonly(self, action: Any) -> bool:
        """True if the action is read-only/idempotent (LW checkpoint, §6.3.3)."""


@dataclasses.dataclass
class MCTSConfig:
    iterations: int = 30
    c_uct: float = 1.2
    expand_width: int = 3           # max children per node
    max_depth: int = 12
    gc_every: int = 0               # 0 = no GC during search
    use_lightweight: bool = True    # route read-only actions to LW checkpoints
    value_isolation: bool = True    # pre-test ckpt + unconditional restore
    seed: int = 0


@dataclasses.dataclass
class MCTSStats:
    iterations: int = 0
    restores: int = 0
    checkpoints: int = 0
    lw_checkpoints: int = 0
    fast_restores: int = 0
    slow_restores: int = 0
    time_restore_s: float = 0.0
    time_checkpoint_s: float = 0.0
    time_action_s: float = 0.0
    time_eval_s: float = 0.0
    best_value: float = 0.0
    nodes: int = 0


class MCTS:
    def __init__(self, sm: StateManager, task: AgentTask, cfg: MCTSConfig = MCTSConfig()):
        self.sm = sm
        self.task = task
        self.cfg = cfg
        self.stats = MCTSStats()
        # per-ckpt search metadata beyond SnapshotNode's visits/value
        self.depth: Dict[int, int] = {}
        self.untried: Dict[int, List[Any]] = {}

    # -------------------------------------------------------------- helpers
    def _uct(self, parent_visits: int, node) -> float:
        if node.visits == 0:
            return float("inf")
        exploit = node.value / node.visits
        explore = self.cfg.c_uct * math.sqrt(math.log(max(parent_visits, 1)) / node.visits)
        return exploit + explore

    def _select(self, root_id: int) -> int:
        """UCT descent to a node with untried actions (or a leaf)."""
        cur = self.sm.node(root_id)
        while True:
            if self.untried.get(cur.ckpt_id) or cur.terminal:
                return cur.ckpt_id
            live_children = [
                self.sm.node(c)
                for c in cur.children
                if c in self.depth and not self.sm.node(c).reclaimed
            ]
            if not live_children:
                return cur.ckpt_id
            cur = max(live_children, key=lambda n: self._uct(cur.visits, n))

    def _backprop(self, ckpt_id: int, value: float) -> None:
        walk: Optional[int] = ckpt_id
        while walk is not None:
            node = self.sm.node(walk)
            node.visits += 1
            node.value += value
            walk = node.parent_id

    def _register(self, ckpt_id: int, depth: int, seed: int) -> None:
        self.depth[ckpt_id] = depth
        node = self.sm.node(ckpt_id)
        node.terminal = self.task.is_terminal(self.sm.sandbox) or depth >= self.cfg.max_depth
        if node.terminal:
            node.expandable = False
            self.untried[ckpt_id] = []
        else:
            actions = list(self.task.propose_actions(self.sm.sandbox, seed))
            self.untried[ckpt_id] = actions[: self.cfg.expand_width]
            node.expandable = bool(self.untried[ckpt_id])
        self.stats.nodes += 1

    # ------------------------------------------------------------------ run
    def run(self) -> MCTSStats:
        cfg, sm, task, st = self.cfg, self.sm, self.task, self.stats

        t0 = time.perf_counter()
        root = sm.checkpoint()
        st.time_checkpoint_s += time.perf_counter() - t0
        st.checkpoints += 1
        self._register(root, 0, cfg.seed)

        for it in range(cfg.iterations):
            st.iterations += 1
            # 1. selection
            target = self._select(root)
            # 2. rollback to the selected node (the paper's critical path)
            if sm.current != target:
                t0 = time.perf_counter()
                mode = sm.restore(target)
                st.time_restore_s += time.perf_counter() - t0
                st.restores += 1
                if mode.startswith("fast"):
                    st.fast_restores += 1
                else:
                    st.slow_restores += 1
            node = sm.node(target)
            if node.terminal:
                t0 = time.perf_counter()
                value = task.evaluate(sm.sandbox)
                st.time_eval_s += time.perf_counter() - t0
                self._backprop(target, value)
                continue
            # 3. expansion: apply one untried action, checkpoint the child
            actions = self.untried[target]
            if not actions:
                node.expandable = False
                self._backprop(target, 0.0)
                continue
            action = actions.pop(0)
            if not actions:
                node.expandable = False
            t0 = time.perf_counter()
            task.apply_action(sm.sandbox, action)
            st.time_action_s += time.perf_counter() - t0

            lw = cfg.use_lightweight and task.is_readonly(action)
            t0 = time.perf_counter()
            child = sm.checkpoint(lightweight=lw, actions=(action,) if lw else ())
            st.time_checkpoint_s += time.perf_counter() - t0
            st.checkpoints += 1
            if lw:
                st.lw_checkpoints += 1
            self._register(child, self.depth[target] + 1, cfg.seed + it + 1)

            # 4. evaluation under value-time isolation
            t0 = time.perf_counter()
            if cfg.value_isolation:
                value = sm.isolated_eval(lambda sb: task.evaluate(sb))
            else:
                value = task.evaluate(sm.sandbox)
            st.time_eval_s += time.perf_counter() - t0
            st.best_value = max(st.best_value, value)

            # 5. backprop
            self._backprop(child, value)

            if cfg.gc_every and (it + 1) % cfg.gc_every == 0:
                reachability_gc(sm)

        return st

    # -------------------------------------------------------- result access
    def best_leaf(self) -> Optional[int]:
        best, best_v = None, -1.0
        for node in self.sm.live_nodes():
            if node.visits and node.terminal:
                v = node.value / node.visits
                if v > best_v:
                    best, best_v = node.ckpt_id, v
        if best is None:
            for node in self.sm.live_nodes():
                if node.visits:
                    v = node.value / node.visits
                    if v > best_v:
                        best, best_v = node.ckpt_id, v
        return best
