"""UCT Monte-Carlo Tree Search over DeltaState checkpoints.

The paper's primary workload (SWE-Search-style MCTS, §2.1/§6.2.1): every
expansion checkpoints at the parent node and rolls back to arbitrary
ancestors, so C/R latency lands on the critical path once per iteration.

The search tree *is* the snapshot index tree: selection walks SnapshotNodes,
expansion = ``restore(parent) → act → checkpoint``, evaluation runs under
``isolated_eval`` (value-time test isolation, §4.3), and the reachability
GC's ``expandable``/``terminal`` flags are maintained here.

Two drivers share the statistics and selection policy:

* **Serial** (``parallel_leaves=1``, the paper's baseline): one live
  sandbox, rollback-in-place per iteration.
* **Parallel** (``parallel_leaves=k>1``): each batch selects ``k`` leaves
  under a virtual loss, *forks* a live sandbox per leaf from its checkpoint
  through :class:`~repro.core.sandbox_tree.SandboxTree` (template fork +
  shared-layer namespace view — no restore of the trunk), and explores them
  concurrently on a thread pool.  Child checkpoints ride DeltaCR's FIFO
  dump worker and the scheduler's DumpGate exactly like a
  ``checkpoint_burst`` storm.  Value-time isolation comes for free: the
  evaluation runs on the disposable fork *after* its checkpoint froze the
  node, so test side effects die with the fork instead of needing a
  pre-test checkpoint + unconditional rollback.  Under a fixed wall-clock
  budget the parallel driver explores ≈``k×`` the nodes whenever action
  execution (tool calls, LLM round-trips) dominates — the paper's "explore
  substantially more nodes under fixed time budgets" claim, made concrete
  in ``benchmarks/table3_fork_fanout.py``.
"""
from __future__ import annotations

import dataclasses
import math
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Protocol, Sequence, Tuple

from repro.core import StateManager, Sandbox, SandboxTree, reachability_gc

__all__ = ["MCTSConfig", "AgentTask", "MCTS", "MCTSStats"]


class AgentTask(Protocol):
    """The environment an agent explores inside the sandbox."""

    def propose_actions(self, sandbox: Sandbox, rng_seed: int) -> Sequence[Any]:
        """Candidate actions at the current state (the LLM proposal step)."""

    def apply_action(self, sandbox: Sandbox, action: Any) -> None:
        """Execute one action (mutates fs/proc; may call the engine)."""

    def evaluate(self, sandbox: Sandbox) -> float:
        """Value estimate in [0,1]; may have side effects (run under
        isolated_eval)."""

    def is_terminal(self, sandbox: Sandbox) -> bool: ...

    def is_readonly(self, action: Any) -> bool:
        """True if the action is read-only/idempotent (LW checkpoint, §6.3.3)."""


@dataclasses.dataclass
class MCTSConfig:
    iterations: int = 30
    c_uct: float = 1.2
    expand_width: int = 3           # max children per node
    max_depth: int = 12
    gc_every: int = 0               # 0 = no GC during search
    use_lightweight: bool = True    # route read-only actions to LW checkpoints
    value_isolation: bool = True    # pre-test ckpt + unconditional restore
    seed: int = 0
    dump: bool = True               # durable dumps per checkpoint (False =
                                    # template-only nodes: pure search speed)
    # -- parallel driver -------------------------------------------------
    parallel_leaves: int = 1        # >1: fork-based concurrent expansion
    time_budget_s: Optional[float] = None   # stop when the budget is spent


@dataclasses.dataclass
class MCTSStats:
    iterations: int = 0
    restores: int = 0
    checkpoints: int = 0
    lw_checkpoints: int = 0
    fast_restores: int = 0
    slow_restores: int = 0
    forks: int = 0                  # parallel driver: sandbox forks
    parallel_batches: int = 0
    time_restore_s: float = 0.0
    time_checkpoint_s: float = 0.0
    time_action_s: float = 0.0
    time_eval_s: float = 0.0
    best_value: float = 0.0
    nodes: int = 0
    wall_s: float = 0.0


class MCTS:
    def __init__(
        self,
        sm: StateManager,
        task: AgentTask,
        cfg: Optional[MCTSConfig] = None,
        *,
        tree: Optional[SandboxTree] = None,
        scheduler: Optional[Any] = None,
    ):
        self.sm = sm
        self.task = task
        # per-instance config: a shared default instance would alias mutable
        # search tuning across every MCTS in the process
        self.cfg = cfg if cfg is not None else MCTSConfig()
        self.tree = tree
        # serving-loop integration: each parallel worker's forked sandbox is
        # admitted into this scheduler's continuous batching for the leaf's
        # lifetime, so task actions can decode through ``scheduler.generate``
        # (engine.step is not thread-safe; the shared batch is)
        self.scheduler = scheduler
        self.stats = MCTSStats()
        # per-ckpt search metadata beyond SnapshotNode's visits/value
        self.depth: Dict[int, int] = {}
        self.untried: Dict[int, List[Any]] = {}
        self._stats_lock = threading.Lock()
        # sandbox ids this run's workers forked and have not yet released —
        # the crash-path cleanup set (a caller-supplied tree may hold other
        # live children that are not ours to tear down)
        self._run_forks: set = set()

    # -------------------------------------------------------------- helpers
    def _uct(self, parent_visits: int, node) -> float:
        if node.visits == 0:
            return float("inf")
        exploit = node.value / node.visits
        explore = self.cfg.c_uct * math.sqrt(math.log(max(parent_visits, 1)) / node.visits)
        return exploit + explore

    def _select(self, root_id: int) -> int:
        """UCT descent to a node with untried actions (or a leaf)."""
        cur = self.sm.node(root_id)
        while True:
            if self.untried.get(cur.ckpt_id) or cur.terminal:
                return cur.ckpt_id
            live_children = [
                self.sm.node(c)
                for c in cur.children
                if c in self.depth and not self.sm.node(c).reclaimed
            ]
            if not live_children:
                return cur.ckpt_id
            cur = max(live_children, key=lambda n: self._uct(cur.visits, n))

    def _backprop(self, ckpt_id: int, value: float) -> None:
        walk: Optional[int] = ckpt_id
        while walk is not None:
            node = self.sm.node(walk)
            node.visits += 1
            node.value += value
            walk = node.parent_id

    def _virtual_loss(self, ckpt_id: int, delta: int) -> None:
        """Discourage (or re-allow) concurrent selection of one path.

        A visit bump with zero value along the path to the root — the
        standard parallel-MCTS device so the k selections of one batch
        spread over the tree instead of piling onto a single leaf."""
        walk: Optional[int] = ckpt_id
        while walk is not None:
            node = self.sm.node(walk)
            node.visits += delta
            walk = node.parent_id

    def _register(
        self, ckpt_id: int, depth: int, seed: int, *, sandbox: Optional[Sandbox] = None
    ) -> None:
        sandbox = sandbox if sandbox is not None else self.sm.sandbox
        self.depth[ckpt_id] = depth
        node = self.sm.node(ckpt_id)
        node.terminal = self.task.is_terminal(sandbox) or depth >= self.cfg.max_depth
        if node.terminal:
            node.expandable = False
            self.untried[ckpt_id] = []
        else:
            actions = list(self.task.propose_actions(sandbox, seed))
            self.untried[ckpt_id] = actions[: self.cfg.expand_width]
            node.expandable = bool(self.untried[ckpt_id])
        self.stats.nodes += 1

    def _register_explored(
        self,
        ckpt_id: int,
        depth: int,
        actions: List[Any],
        terminal: bool,
    ) -> None:
        """Driver-thread registration from a worker's explored snapshot."""
        self.depth[ckpt_id] = depth
        node = self.sm.node(ckpt_id)
        node.terminal = terminal
        node.expandable = bool(actions) and not terminal
        self.untried[ckpt_id] = [] if terminal else list(actions)
        self.stats.nodes += 1

    # ------------------------------------------------------------------ run
    def run(self) -> MCTSStats:
        t_run = time.perf_counter()
        if self.cfg.parallel_leaves > 1:
            out = self._run_parallel()
        else:
            out = self._run_serial()
        out.wall_s = time.perf_counter() - t_run
        return out

    def _deadline(self) -> Optional[float]:
        if self.cfg.time_budget_s is None:
            return None
        return time.monotonic() + self.cfg.time_budget_s

    # ----------------------------------------------------------- serial run
    def _run_serial(self) -> MCTSStats:
        cfg, sm, task, st = self.cfg, self.sm, self.task, self.stats

        t0 = time.perf_counter()
        root = sm.checkpoint(dump=cfg.dump)
        st.time_checkpoint_s += time.perf_counter() - t0
        st.checkpoints += 1
        self._register(root, 0, cfg.seed)
        deadline = self._deadline()

        for it in range(cfg.iterations):
            if deadline is not None and time.monotonic() >= deadline:
                break
            st.iterations += 1
            # 1. selection
            target = self._select(root)
            # 2. rollback to the selected node (the paper's critical path)
            if sm.current != target:
                t0 = time.perf_counter()
                mode = sm.restore(target)
                st.time_restore_s += time.perf_counter() - t0
                st.restores += 1
                if mode.startswith("fast"):
                    st.fast_restores += 1
                else:
                    st.slow_restores += 1
            node = sm.node(target)
            if node.terminal:
                t0 = time.perf_counter()
                value = task.evaluate(sm.sandbox)
                st.time_eval_s += time.perf_counter() - t0
                self._backprop(target, value)
                continue
            # 3. expansion: apply one untried action, checkpoint the child
            actions = self.untried[target]
            if not actions:
                node.expandable = False
                self._backprop(target, 0.0)
                continue
            action = actions.pop(0)
            if not actions:
                node.expandable = False
            t0 = time.perf_counter()
            task.apply_action(sm.sandbox, action)
            st.time_action_s += time.perf_counter() - t0

            lw = cfg.use_lightweight and task.is_readonly(action)
            t0 = time.perf_counter()
            child = sm.checkpoint(
                lightweight=lw, actions=(action,) if lw else (), dump=cfg.dump
            )
            st.time_checkpoint_s += time.perf_counter() - t0
            st.checkpoints += 1
            if lw:
                st.lw_checkpoints += 1
            self._register(child, self.depth[target] + 1, cfg.seed + it + 1)

            # 4. evaluation under value-time isolation
            t0 = time.perf_counter()
            if cfg.value_isolation:
                value = sm.isolated_eval(lambda sb: task.evaluate(sb))
            else:
                value = task.evaluate(sm.sandbox)
            st.time_eval_s += time.perf_counter() - t0
            st.best_value = max(st.best_value, value)

            # 5. backprop
            self._backprop(child, value)

            if cfg.gc_every and (it + 1) % cfg.gc_every == 0:
                reachability_gc(sm)

        return st

    # --------------------------------------------------------- parallel run
    def _run_parallel(self) -> MCTSStats:
        cfg, sm, st = self.cfg, self.sm, self.stats
        tree = self.tree if self.tree is not None else SandboxTree(sm)
        self.tree = tree

        t0 = time.perf_counter()
        root = sm.checkpoint(dump=cfg.dump)
        st.time_checkpoint_s += time.perf_counter() - t0
        st.checkpoints += 1
        self._register(root, 0, cfg.seed)
        deadline = self._deadline()

        pool = ThreadPoolExecutor(
            max_workers=cfg.parallel_leaves, thread_name_prefix="mcts-leaf"
        )
        try:
            it = 0
            while it < cfg.iterations:
                if deadline is not None and time.monotonic() >= deadline:
                    break
                batch = min(cfg.parallel_leaves, cfg.iterations - it)
                # 1. batched selection under virtual loss (driver thread)
                picks: List[Tuple[int, Optional[Any]]] = []
                for _ in range(batch):
                    target = self._select(root)
                    node = sm.node(target)
                    action = None
                    pending = self.untried.get(target)
                    if pending and not node.terminal:
                        action = pending.pop(0)
                        if not pending:
                            node.expandable = False
                    self._virtual_loss(target, +1)
                    picks.append((target, action))
                # 2. fork + explore concurrently
                futs = [
                    pool.submit(self._explore_leaf, tree, t, a, cfg.seed + it + i + 1)
                    for i, (t, a) in enumerate(picks)
                ]
                # Drain EVERY future before acting on any error: virtual
                # losses must all revert and every successful worker's child
                # must be registered, or the tree would keep inflated visit
                # counts and unreachable-but-GC-protected orphan nodes.
                errors: List[BaseException] = []
                for (target, action), fut in zip(picks, futs):
                    try:
                        child, value, actions, terminal = fut.result()
                    except BaseException as exc:
                        self._virtual_loss(target, -1)
                        errors.append(exc)
                        continue
                    self._virtual_loss(target, -1)
                    st.iterations += 1
                    st.best_value = max(st.best_value, value)
                    if child is None:        # evaluation-only visit
                        self._backprop(target, value)
                        continue
                    self._register_explored(
                        child, self.depth[target] + 1, actions, terminal
                    )
                    self._backprop(child, value)
                if errors:
                    raise errors[0]
                it += batch
                st.parallel_batches += 1
                if cfg.gc_every and st.parallel_batches % max(1, cfg.gc_every // batch) == 0:
                    reachability_gc(sm)
        finally:
            pool.shutdown(wait=True)
            # release only the forks THIS run created (workers normally
            # already did; this is the crash path) — a caller-supplied tree
            # may hold live children that are not ours to tear down
            with self._stats_lock:
                leaked = list(self._run_forks)
                self._run_forks.clear()
            for sid in leaked:
                tree.release(sid)
        return st

    def _explore_leaf(
        self, tree: SandboxTree, target: int, action: Optional[Any], seed: int
    ) -> Tuple[Optional[int], float, List[Any], bool]:
        """Worker body: fork → act → checkpoint → evaluate → release.

        Returns ``(child_ckpt | None, value, proposed_actions, terminal)``.
        The evaluation runs *after* the child checkpoint froze the node, on
        the disposable fork — its side effects land in the fork's fresh
        upper and die with the release (value-time isolation for free)."""
        cfg, task, st = self.cfg, self.task, self.stats
        sandbox = tree.fork(target, 1)[0]
        with self._stats_lock:
            st.forks += 1
            self._run_forks.add(sandbox.sandbox_id)
        # Serving-loop admission: the fork joins the scheduler's continuous
        # batching for this leaf's lifetime, so apply_action/evaluate can
        # decode through ``scheduler.generate`` — sibling leaves' requests
        # batch into one engine step, CoW keeps their pages shared.
        sched_sid = None
        if self.scheduler is not None:
            sched_sid = self.scheduler.admit_forked(sandbox.proc)
            sandbox.sched_sid = sched_sid
        try:
            if action is None:
                t0 = time.perf_counter()
                value = task.evaluate(sandbox)
                with self._stats_lock:
                    st.time_eval_s += time.perf_counter() - t0
                return None, value, [], False

            t0 = time.perf_counter()
            task.apply_action(sandbox, action)
            t_action = time.perf_counter() - t0

            # Read-only actions route to metadata-only LW markers exactly
            # like the serial driver (§6.3.3): no layer freeze, no dump — a
            # later fork/restore of the node replays the action.
            lw = cfg.use_lightweight and task.is_readonly(action)
            t0 = time.perf_counter()
            if lw:
                child = tree.checkpoint_lightweight(sandbox.sandbox_id, (action,))
            else:
                child = tree.checkpoint(sandbox.sandbox_id, dump=cfg.dump)
            t_ckpt = time.perf_counter() - t0

            # Registration data (terminal flag, untried actions) must be
            # derived from the frozen checkpoint state, BEFORE evaluate()'s
            # side effects land in the fork — mirroring the serial driver,
            # which registers the child and only then evaluates under
            # isolation.  The evaluation's pollution then dies with the fork.
            try:
                t0 = time.perf_counter()
                terminal = (
                    task.is_terminal(sandbox)
                    or self.depth[target] + 1 >= cfg.max_depth
                )
                actions: List[Any] = []
                if not terminal:
                    actions = list(task.propose_actions(sandbox, seed))[: cfg.expand_width]
                value = task.evaluate(sandbox)
                t_eval = time.perf_counter() - t0
            except BaseException:
                # the adopted child would otherwise be an orphan the driver
                # never registers but GC protects forever — reclaim it
                tree.release(sandbox.sandbox_id)
                try:
                    self.sm.reclaim(child)
                except Exception:
                    pass
                raise

            with self._stats_lock:
                st.time_action_s += t_action
                st.time_checkpoint_s += t_ckpt
                st.time_eval_s += t_eval
                st.checkpoints += 1
                if lw:
                    st.lw_checkpoints += 1
            return child, value, actions, terminal
        finally:
            if sched_sid is not None:
                try:
                    # rebind: the scheduler may have suspended+resumed the
                    # session (new identity); the tree releases what's live
                    sandbox.proc = self.scheduler.detach(sched_sid)
                except Exception:
                    pass
            tree.release(sandbox.sandbox_id)
            with self._stats_lock:
                self._run_forks.discard(sandbox.sandbox_id)

    # -------------------------------------------------------- result access
    def best_leaf(self) -> Optional[int]:
        best, best_v = None, -1.0
        for node in self.sm.live_nodes():
            if node.visits and node.terminal:
                v = node.value / node.visits
                if v > best_v:
                    best, best_v = node.ckpt_id, v
        if best is None:
            for node in self.sm.live_nodes():
                if node.visits:
                    v = node.value / node.visits
                    if v > best_v:
                        best, best_v = node.ckpt_id, v
        return best
