"""Best-of-N / RL rollout fan-out over warm templates (paper §6.2.2).

Each training step forks N independent sandboxes from the same warm
starting state, runs them as rollouts, scores them, and tears them down.
Fork latency directly bounds training throughput, so the primitives here
are:

* ``fork_n``         — N bare template forks (page-table copies + refcount
                       bumps) with latency percentiles and footprint
                       accounting — the Table 3 / Fig 7(a) analogue.
* ``fork_sandboxes`` — N **live sandboxes** from a checkpoint through a
                       :class:`~repro.core.sandbox_tree.SandboxTree`:
                       process template fork *plus* a shared-layer
                       namespace view per child, i.e. the end-to-end cost a
                       real fan-out pays.
* ``rollout_fanout`` — the full RL-step substrate path over either source:
                       fan-out + (optionally threaded) rollouts + teardown.
                       Passing a ``SandboxTree`` + ``ckpt_id`` drives real
                       sandbox forks; passing a bare ``ForkableState`` keeps
                       the historical process-only behavior.

``sync_gpu_occupation`` reproduces the Fig 7(c) model:
    occ = (T_gen + T_train) / (T_sandbox + T_gen + T_train).
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.deltacr import DeltaCR, ForkableState
from repro.core.sandbox_tree import SandboxTree
from repro.core.state_manager import Sandbox

__all__ = [
    "FanoutResult",
    "checkpoint_burst",
    "decode_fanout",
    "fork_n",
    "fork_sandboxes",
    "rollout_fanout",
    "sync_gpu_occupation",
    "staleness",
]


@dataclasses.dataclass
class FanoutResult:
    n: int
    fork_ms: List[float]                 # per-fork wall ms
    total_ms: float
    resident_bytes: int                  # summed attributable footprint
    forks_per_s: float

    @property
    def p50_ms(self) -> float:
        return float(np.percentile(self.fork_ms, 50))

    @property
    def p99_ms(self) -> float:
        return float(np.percentile(self.fork_ms, 99))


def _result(children: Sequence[Any], fork_ms: List[float], total_ms: float) -> FanoutResult:
    resident = 0
    for c in children:
        state = c.proc if isinstance(c, Sandbox) else c
        rb = getattr(state, "resident_bytes", None)
        if callable(rb):
            resident += rb()
    return FanoutResult(
        n=len(children),
        fork_ms=fork_ms,
        total_ms=total_ms,
        resident_bytes=resident,
        forks_per_s=len(children) / max(total_ms / 1e3, 1e-9),
    )


def fork_n(template: ForkableState, n: int) -> Tuple[List[ForkableState], FanoutResult]:
    """Fork ``n`` children from one frozen template, timing each fork."""
    children: List[ForkableState] = []
    fork_ms: List[float] = []
    t_start = time.perf_counter()
    for _ in range(n):
        t0 = time.perf_counter()
        children.append(template.fork())
        fork_ms.append((time.perf_counter() - t0) * 1e3)
    total_ms = (time.perf_counter() - t_start) * 1e3
    return children, _result(children, fork_ms, total_ms)


def fork_sandboxes(
    tree: SandboxTree, ckpt_id: int, n: int
) -> Tuple[List[Sandbox], FanoutResult]:
    """Fork ``n`` live sandboxes from a checkpoint, timing each fork.

    The end-to-end fan-out primitive: each fork is a DeltaCR template fork
    *plus* a fresh namespace view over the shared layer store — what a real
    rollout pays before its first action.  Callers release children via
    ``tree.release(sandbox.sandbox_id)`` (or ``tree.release_all()``)."""
    children: List[Sandbox] = []
    fork_ms: List[float] = []
    t_start = time.perf_counter()
    for _ in range(n):
        t0 = time.perf_counter()
        children.append(tree.fork(ckpt_id, 1)[0])
        fork_ms.append((time.perf_counter() - t0) * 1e3)
    total_ms = (time.perf_counter() - t_start) * 1e3
    return children, _result(children, fork_ms, total_ms)


def rollout_fanout(
    source: Union[ForkableState, SandboxTree],
    n: int,
    rollout_fn: Callable[[Any, int], float],
    *,
    ckpt_id: Optional[int] = None,
    teardown: bool = True,
    workers: int = 0,
) -> Tuple[List[float], FanoutResult]:
    """Fork N children, run ``rollout_fn(child, i) -> reward``, tear down.

    The full RL-step substrate path: fan-out + rollouts + release.  With a
    :class:`SandboxTree` source (``ckpt_id`` required) the children are live
    sandboxes sharing every frozen layer; ``workers > 1`` runs the rollouts
    on a thread pool — sound because sibling sandboxes are mutually
    isolated by construction (CoW process state, private fs uppers)."""
    if isinstance(source, SandboxTree):
        if ckpt_id is None:
            raise ValueError("SandboxTree fan-out requires ckpt_id")
        children, result = fork_sandboxes(source, ckpt_id, n)
    else:
        children, result = fork_n(source, n)

    def _release_children() -> None:
        for child in children:
            if isinstance(source, SandboxTree):
                source.release(child.sandbox_id)
            else:
                child.release()

    try:
        if workers > 1:
            with ThreadPoolExecutor(max_workers=workers, thread_name_prefix="rollout") as pool:
                rewards = list(pool.map(rollout_fn, children, range(len(children))))
        else:
            rewards = [rollout_fn(child, i) for i, child in enumerate(children)]
    except BaseException:
        # a failed rollout must not leak the fan-out: live children would
        # stay resident and keep their base checkpoint pinned forever
        _release_children()
        raise

    if teardown:
        _release_children()
    return rewards, result


def decode_fanout(
    tree: SandboxTree,
    ckpt_id: int,
    n: int,
    scheduler,
    k_tokens: int,
    *,
    actions: Optional[Sequence[int]] = None,
    release: bool = True,
) -> Tuple[List[List[int]], List[Sandbox], FanoutResult]:
    """Fork ``n`` live decoders from one checkpoint and decode ``k_tokens``
    each through the scheduler's continuous batching — the serving-loop
    fan-out primitive end to end.

    Each child is admitted via ``Scheduler.admit_forked`` (the fork itself
    copies zero KV-block bytes — CoW pages stay shared until the first
    divergent write); ``actions`` optionally force-feeds child ``i``'s
    pending token (the divergence point — a search step's chosen action)
    before decoding.  All ``n`` requests drain through batched ``step()``
    calls, so siblings decode together.  Returns the per-child sampled
    token streams, the sandboxes (empty list when ``release``), and the
    fork accounting."""
    children, result = fork_sandboxes(tree, ckpt_id, n)
    sids: List[int] = []
    try:
        for i, sandbox in enumerate(children):
            if actions is not None:
                # overwrite the pending token: K/V not yet written, so this
                # is the first divergent write's *cause*, not a write itself
                sandbox.proc.tokens[-1] = int(actions[i])
            sid = scheduler.admit_forked(sandbox.proc)
            sandbox.sched_sid = sid
            sids.append(sid)
        futs = [scheduler.request_tokens(sid, k_tokens) for sid in sids]
        while any(not f.done() for f in futs):
            scheduler.step()
        streams = [list(f.result()) for f in futs]
    finally:
        for sandbox, sid in zip(children, sids):
            try:
                sandbox.proc = scheduler.detach(sid)
            except Exception:
                pass
        if release:
            for sandbox in children:
                tree.release(sandbox.sandbox_id)
    return streams, ([] if release else children), result


def checkpoint_burst(
    cr: DeltaCR,
    states: Sequence[ForkableState],
    ckpt_ids: Sequence[int],
    parent_ckpt: Union[Optional[int], Sequence[Optional[int]]] = None,
    *,
    priority: str = "bg",
    wait: bool = False,
) -> Tuple[List[Any], float]:
    """Checkpoint a fan-out burst without head-of-line-blocking decode.

    The deep fan-outs of MCTS expansion and RL rollouts park many sibling
    states at once.  Submitting each dump and waiting would serialize the
    burst on durable-dump latency; this instead enqueues every dump on
    DeltaCR's FIFO worker in one pass — the streaming engine's QoS gate
    bounds in-flight windows and demotes ``priority="bg"`` dumps while the
    scheduler has runnable sessions, so the storm drains in the background
    masked by inference.  ``parent_ckpt`` may be a single id (all states
    dump against one parent — the classic same-template burst) or one id
    per state (a SandboxTree batch whose children descend from different
    nodes).  Returns the dump futures (resolve when durable) and the
    synchronous submit cost in ms (forks + queue pushes only).
    """
    if len(states) != len(ckpt_ids):
        raise ValueError("states and ckpt_ids must align")
    if isinstance(parent_ckpt, (list, tuple)):
        if len(parent_ckpt) != len(states):
            raise ValueError("per-state parents must align with states")
        parents: Sequence[Optional[int]] = parent_ckpt
    else:
        parents = [parent_ckpt] * len(states)
    t0 = time.perf_counter()
    for state, ckpt_id, parent in zip(states, ckpt_ids, parents):
        cr.checkpoint(state, ckpt_id, parent, priority=priority)
    submit_ms = (time.perf_counter() - t0) * 1e3
    futs = [cr.dump_future(c) for c in ckpt_ids]
    if wait:
        for fut in futs:
            if fut is not None:
                fut.result()
    return futs, submit_ms


def sync_gpu_occupation(t_sandbox_s: float, t_gen_s: float, t_train_s: float) -> float:
    """Expected synchronous-RL accelerator occupation (Fig 7c)."""
    return (t_gen_s + t_train_s) / max(t_sandbox_s + t_gen_s + t_train_s, 1e-12)


def staleness(t_sandbox_s: float, t_gen_s: float, t_train_s: float) -> float:
    """Async decoupled-trainer staleness model (§6.2.2): how many rollout
    generations the trainer outpaces the rollouter by."""
    return (t_sandbox_s + t_gen_s) / max(t_train_s, 1e-12) - 1.0
