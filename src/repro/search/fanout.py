"""Best-of-N / RL rollout fan-out over warm templates (paper §6.2.2).

Each training step forks N independent sandboxes from the same warm
starting state, runs them as rollouts, scores them, and tears them down.
Fork latency directly bounds training throughput, so the primitive here is
``fork_n``: N template forks (page-table copies + refcount bumps) with
latency percentiles and footprint accounting — the Table 3 / Fig 7(a)
analogue.

``sync_gpu_occupation`` reproduces the Fig 7(c) model:
    occ = (T_gen + T_train) / (T_sandbox + T_gen + T_train).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.deltacr import DeltaCR, ForkableState

__all__ = [
    "FanoutResult",
    "checkpoint_burst",
    "fork_n",
    "rollout_fanout",
    "sync_gpu_occupation",
    "staleness",
]


@dataclasses.dataclass
class FanoutResult:
    n: int
    fork_ms: List[float]                 # per-fork wall ms
    total_ms: float
    resident_bytes: int                  # summed attributable footprint
    forks_per_s: float

    @property
    def p50_ms(self) -> float:
        return float(np.percentile(self.fork_ms, 50))

    @property
    def p99_ms(self) -> float:
        return float(np.percentile(self.fork_ms, 99))


def fork_n(template: ForkableState, n: int) -> Tuple[List[ForkableState], FanoutResult]:
    """Fork ``n`` children from one frozen template, timing each fork."""
    children: List[ForkableState] = []
    fork_ms: List[float] = []
    t_start = time.perf_counter()
    for _ in range(n):
        t0 = time.perf_counter()
        children.append(template.fork())
        fork_ms.append((time.perf_counter() - t0) * 1e3)
    total_ms = (time.perf_counter() - t_start) * 1e3
    resident = 0
    for c in children:
        rb = getattr(c, "resident_bytes", None)
        if callable(rb):
            resident += rb()
    return children, FanoutResult(
        n=n,
        fork_ms=fork_ms,
        total_ms=total_ms,
        resident_bytes=resident,
        forks_per_s=n / max(total_ms / 1e3, 1e-9),
    )


def rollout_fanout(
    template: ForkableState,
    n: int,
    rollout_fn: Callable[[ForkableState, int], float],
    *,
    teardown: bool = True,
) -> Tuple[List[float], FanoutResult]:
    """Fork N children, run ``rollout_fn(child, i) -> reward``, tear down.

    The full RL-step substrate path: fan-out + rollouts + release."""
    children, result = fork_n(template, n)
    rewards = [rollout_fn(child, i) for i, child in enumerate(children)]
    if teardown:
        for child in children:
            child.release()
    return rewards, result


def checkpoint_burst(
    cr: DeltaCR,
    states: Sequence[ForkableState],
    ckpt_ids: Sequence[int],
    parent_ckpt: Optional[int] = None,
    *,
    priority: str = "bg",
    wait: bool = False,
) -> Tuple[List[Any], float]:
    """Checkpoint a fan-out burst without head-of-line-blocking decode.

    The deep fan-outs of MCTS expansion and RL rollouts park many sibling
    states at once.  Submitting each dump and waiting would serialize the
    burst on durable-dump latency; this instead enqueues every dump on
    DeltaCR's FIFO worker in one pass — the streaming engine's QoS gate
    bounds in-flight windows and demotes ``priority="bg"`` dumps while the
    scheduler has runnable sessions, so the storm drains in the background
    masked by inference.  Returns the dump futures (resolve when durable)
    and the synchronous submit cost in ms (forks + queue pushes only).
    """
    if len(states) != len(ckpt_ids):
        raise ValueError("states and ckpt_ids must align")
    t0 = time.perf_counter()
    for state, ckpt_id in zip(states, ckpt_ids):
        cr.checkpoint(state, ckpt_id, parent_ckpt, priority=priority)
    submit_ms = (time.perf_counter() - t0) * 1e3
    futs = [cr.dump_future(c) for c in ckpt_ids]
    if wait:
        for fut in futs:
            if fut is not None:
                fut.result()
    return futs, submit_ms


def sync_gpu_occupation(t_sandbox_s: float, t_gen_s: float, t_train_s: float) -> float:
    """Expected synchronous-RL accelerator occupation (Fig 7c)."""
    return (t_gen_s + t_train_s) / max(t_sandbox_s + t_gen_s + t_train_s, 1e-12)


def staleness(t_sandbox_s: float, t_gen_s: float, t_train_s: float) -> float:
    """Async decoupled-trainer staleness model (§6.2.2): how many rollout
    generations the trainer outpaces the rollouter by."""
    return (t_sandbox_s + t_gen_s) / max(t_train_s, 1e-12) - 1.0
