"""A real-decode :class:`~repro.search.mcts.AgentTask` — search over tokens.

The paper's headline workload is tree search whose *actions are decoded by
the model itself*: a node's candidate actions are the top tokens of its last
logits, applying an action force-feeds that token and decodes ``k_tokens``
more, and the value estimate is read off the resulting distribution.  This
module closes the serving loop for MCTS:

* **Serial driver** (no scheduler): the task decodes the trunk session
  directly through ``engine.step`` — the rollback-in-place baseline.
* **Parallel driver** (``MCTS(scheduler=...)``): each forked leaf is
  admitted into the scheduler's continuous batching for its lifetime, and
  ``apply_action`` decodes through ``scheduler.generate`` — sibling leaves'
  requests coalesce into one batched engine step, while the CoW page pool
  keeps their shared prefix at zero copied bytes.

Everything is deterministic under greedy sampling: forked expansion of a
node is bit-identical to re-prefilling the node's tokens from scratch (the
differential test plane gates on exactly this).
"""
from __future__ import annotations

from typing import Any, List, Optional, Sequence

import numpy as np

from repro.core.state_manager import Sandbox

__all__ = ["DecodeSearchTask"]


class DecodeSearchTask:
    """MCTS task whose sandbox ``proc`` is a live ``PagedSession``.

    * ``propose_actions`` — the ``width`` highest-logit tokens at the node.
    * ``apply_action``    — force the pending token to the action, then
      decode ``k_tokens`` greedily (through the scheduler when the sandbox
      was admitted — ``sandbox.sched_sid`` — else directly on the engine).
    * ``evaluate``        — max softmax probability of the final logits: a
      cheap, deterministic confidence proxy in [0, 1].
    """

    def __init__(
        self,
        engine,
        *,
        scheduler=None,
        k_tokens: int = 4,
        width: int = 3,
        max_len: Optional[int] = None,
    ):
        self.engine = engine
        self.scheduler = scheduler
        self.k_tokens = int(k_tokens)
        self.width = int(width)
        # terminal guard: stop expanding before sessions outgrow max_pages
        psz = engine.pool.page_size
        cap = engine.pool.max_pages * psz
        self.max_len = int(max_len) if max_len is not None else cap - k_tokens - 1

    # ------------------------------------------------------------ protocol
    def propose_actions(self, sandbox: Sandbox, rng_seed: int) -> Sequence[Any]:
        sess = sandbox.proc
        logits = np.asarray(sess.extras["last_logits"], np.float32)
        top = np.argsort(-logits, kind="stable")[: self.width]
        return [int(t) for t in top]

    def apply_action(self, sandbox: Sandbox, action: Any) -> None:
        sched_sid = getattr(sandbox, "sched_sid", None)
        if self.scheduler is not None and sched_sid is not None:
            # the scheduler may have parked+resumed the session (identity
            # change) — always act on the live one, and rebind the sandbox
            sess = self.scheduler.session(sched_sid)
            sandbox.proc = sess
            sess.tokens[-1] = int(action)
            self.scheduler.generate(sched_sid, self.k_tokens)
            sandbox.proc = self.scheduler.session(sched_sid)
        else:
            sess = sandbox.proc
            sess.tokens[-1] = int(action)
            for _ in range(self.k_tokens):
                self.engine.step([sess])

    def evaluate(self, sandbox: Sandbox) -> float:
        sess = sandbox.proc
        logits = np.asarray(sess.extras["last_logits"], np.float64)
        z = logits - logits.max()
        p = np.exp(z)
        return float(p.max() / p.sum())

    def is_terminal(self, sandbox: Sandbox) -> bool:
        return sandbox.proc.seq_len >= self.max_len

    def is_readonly(self, action: Any) -> bool:
        return False                     # decoding always writes KV pages

    # ------------------------------------------------------------- helpers
    def decode_tokens(self, sessions: List[Any], k: int) -> List[List[int]]:
        """Batched greedy decode of ``k`` tokens for a list of sessions
        (test/benchmark convenience — one engine batch per step)."""
        out: List[List[int]] = [[] for _ in sessions]
        for _ in range(k):
            toks = self.engine.step(sessions)
            for i, t in enumerate(toks):
                out[i].append(int(t))
        return out
