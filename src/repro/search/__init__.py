"""Search strategies over DeltaState: MCTS (UCT) + Best-of-N / RL fan-out."""
from .archetypes import ARCHETYPES, ArchetypeSpec, SyntheticAgentTask, build_sandbox_state
from .decode_task import DecodeSearchTask
from .fanout import (
    FanoutResult,
    checkpoint_burst,
    decode_fanout,
    fork_n,
    fork_sandboxes,
    rollout_fanout,
    staleness,
    sync_gpu_occupation,
)
from .mcts import MCTS, AgentTask, MCTSConfig, MCTSStats

__all__ = [
    "ARCHETYPES", "ArchetypeSpec", "SyntheticAgentTask", "build_sandbox_state",
    "DecodeSearchTask",
    "FanoutResult", "checkpoint_burst", "decode_fanout", "fork_n", "fork_sandboxes",
    "rollout_fanout", "staleness", "sync_gpu_occupation",
    "MCTS", "AgentTask", "MCTSConfig", "MCTSStats",
]
