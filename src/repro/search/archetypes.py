"""SWE-bench MCTS workload archetypes (paper §6.1, Table 2).

Four trajectory archetypes parameterize the synthetic agent task used by the
benchmarks; sizes follow the paper's characterization:

* **Django** — fat process: large in-memory heap, moderate repo, moderate edits
* **SymPy** — read-heavy exploration: big repo, many reads, few small writes
* **Scientific** — NumPy-heavy, process-dominated: large arrays mutated per step
* **Tools/small** — lightweight repos and heaps

Each action mutates a dirty fraction of the repo ("files" = fs tensors) and
of the process heap, mirrors a tool invocation (read-only actions are
LW-eligible), and optionally generates tokens through the serving engine.
All mutations are deterministic in the action seed — required for the
rollback-determinism tests.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import CowArrayState, Sandbox

__all__ = ["ArchetypeSpec", "ARCHETYPES", "SyntheticAgentTask", "build_sandbox_state"]


@dataclasses.dataclass(frozen=True)
class ArchetypeSpec:
    name: str
    n_files: int                  # repo tensors
    file_kb: int                  # size of each repo tensor (KiB)
    heap_mb: float                # process heap (CowArrayState arrays)
    heap_arrays: int
    write_files_per_step: int     # files dirtied by a mutating action
    edit_fraction: float          # fraction of a touched file rewritten
    heap_dirty_fraction: float    # heap bytes dirtied per step
    readonly_prob: float          # probability an action is read-only
    tokens_per_step: int          # LLM tokens generated per action (engine mode)


ARCHETYPES: Dict[str, ArchetypeSpec] = {
    "django": ArchetypeSpec(
        "django", n_files=48, file_kb=64, heap_mb=24.0, heap_arrays=6,
        write_files_per_step=4, edit_fraction=0.05, heap_dirty_fraction=0.15,
        readonly_prob=0.45, tokens_per_step=24,
    ),
    "sympy": ArchetypeSpec(
        "sympy", n_files=96, file_kb=64, heap_mb=8.0, heap_arrays=4,
        write_files_per_step=1, edit_fraction=0.02, heap_dirty_fraction=0.05,
        readonly_prob=0.75, tokens_per_step=24,
    ),
    "scientific": ArchetypeSpec(
        "scientific", n_files=24, file_kb=128, heap_mb=32.0, heap_arrays=8,
        write_files_per_step=2, edit_fraction=0.08, heap_dirty_fraction=0.30,
        readonly_prob=0.50, tokens_per_step=24,
    ),
    "tools": ArchetypeSpec(
        "tools", n_files=12, file_kb=16, heap_mb=2.0, heap_arrays=2,
        write_files_per_step=1, edit_fraction=0.10, heap_dirty_fraction=0.10,
        readonly_prob=0.60, tokens_per_step=12,
    ),
}


def build_sandbox_state(
    spec: ArchetypeSpec, fs, *, seed: int = 0
) -> CowArrayState:
    """Populate the DeltaFS repo and return the initial process state."""
    rng = np.random.default_rng(seed)
    file_elems = spec.file_kb * 1024 // 4
    for i in range(spec.n_files):
        fs.write(f"repo/file_{i:04d}", rng.standard_normal(file_elems).astype(np.float32))
    heap_elems = int(spec.heap_mb * (1 << 20)) // 4
    per = max(heap_elems // spec.heap_arrays, 1)
    arrays = {
        f"heap_{j}": rng.standard_normal(per).astype(np.float32)
        for j in range(spec.heap_arrays)
    }
    arrays["cursor"] = np.zeros(4, np.int64)
    return CowArrayState(arrays, hot_keys=tuple(f"heap_{j}" for j in range(min(2, spec.heap_arrays))))


@dataclasses.dataclass(frozen=True)
class Action:
    seed: int
    readonly: bool


class SyntheticAgentTask:
    """AgentTask over (DeltaFS repo, CowArrayState heap) with deterministic
    seed-driven mutations.  ``action_time_s`` models tool-execution latency;
    the LLM round-trip is modeled by the InferenceProxy in engine mode."""

    def __init__(
        self,
        spec: ArchetypeSpec,
        *,
        action_time_s: float = 0.0,
        proxy=None,
        terminal_depth: int = 10_000,
    ):
        self.spec = spec
        self.action_time_s = action_time_s
        self.proxy = proxy
        self.terminal_depth = terminal_depth

    # ------------------------------------------------------------ AgentTask
    def propose_actions(self, sandbox: Sandbox, rng_seed: int) -> Sequence[Action]:
        rng = np.random.default_rng(rng_seed)
        return [
            Action(seed=int(rng.integers(1 << 31)), readonly=bool(rng.random() < self.spec.readonly_prob))
            for _ in range(4)
        ]

    def apply_action(self, sandbox: Sandbox, action: Action) -> None:
        if self.proxy is not None:
            # The LLM round-trip: checkpoint work overlaps this window.
            self.proxy.infer(sandbox.sandbox_id, {"tokens": self.spec.tokens_per_step})
        self._execute(sandbox, action)

    def replay_action(self, sandbox: Sandbox, action: Action) -> None:
        """LW-restore replay: re-execute the recorded tool action with the
        *cached* completion — no LLM round-trip (paper §6.3.3)."""
        self._execute(sandbox, action)

    def _execute(self, sandbox: Sandbox, action: Action) -> None:
        if self.action_time_s:
            import time as _t

            _t.sleep(self.action_time_s)
        rng = np.random.default_rng(action.seed)
        # heap mutation (process dimension) — happens for all actions
        state = sandbox.proc
        if isinstance(state, CowArrayState):
            for key in list(state.keys()):
                if key.startswith("heap_") and rng.random() < self.spec.heap_dirty_fraction * 2:
                    def mutate(arr, _rng=rng):
                        n = max(1, int(arr.size * self.spec.heap_dirty_fraction))
                        idx = _rng.integers(0, arr.size, size=n)
                        arr[idx] = _rng.standard_normal(n).astype(arr.dtype)
                    state.mutate(key, mutate)
            state.mutate("cursor", lambda c: c.__setitem__(0, c[0] + 1))
        if action.readonly:
            # read-only tool (grep/cat/ls): touch fs reads only
            keys = sandbox.fs.keys()
            for k in keys[: min(4, len(keys))]:
                sandbox.fs.read(k)
            return
        # mutating tool (edit/pip install/sed): dirty a few files partially
        file_ids = rng.integers(0, self.spec.n_files, size=self.spec.write_files_per_step)
        for fid in file_ids:
            key = f"repo/file_{int(fid):04d}"
            arr = sandbox.fs.read(key)
            n = max(1, int(arr.size * self.spec.edit_fraction))
            pos = int(rng.integers(0, max(arr.size - n, 1)))
            arr[pos : pos + n] = rng.standard_normal(n).astype(arr.dtype)
            sandbox.fs.write(key, arr)

    def evaluate(self, sandbox: Sandbox) -> float:
        """Value model: deterministic hash of the cursor + a test side effect
        (writes __pycache__-style junk that value-time isolation must undo)."""
        state = sandbox.proc
        cursor = int(state.get("cursor")[0]) if isinstance(state, CowArrayState) else 0
        # side effect: tests leave artifacts
        sandbox.fs.write("repo/__pycache__", np.full(256, cursor, np.int32))
        rng = np.random.default_rng(cursor + 17)
        return float(rng.random())

    def is_terminal(self, sandbox: Sandbox) -> bool:
        state = sandbox.proc
        if isinstance(state, CowArrayState):
            return int(state.get("cursor")[0]) >= self.terminal_depth
        return False

    def is_readonly(self, action: Action) -> bool:
        return action.readonly
