"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2.

Mamba+attention 1:7 interleave (1 attn per 8-layer period), MoE every other
layer.  72 = 9 periods of 8.  [arXiv:2403.19887; hf]
"""
from .base import ModelConfig, Stage, lm_shapes

_PERIOD = (
    ("mamba", "mlp"),
    ("mamba", "moe"),
    ("mamba", "mlp"),
    ("mamba", "moe"),
    ("attn", "mlp"),
    ("mamba", "moe"),
    ("mamba", "mlp"),
    ("mamba", "moe"),
)

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    stages=(Stage(period=_PERIOD, n_periods=9),),
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    n_experts=16,
    top_k=2,
    moe_d_ff=24576,
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    activation="silu",
    attn_shard="kv",
    tie_embeddings=False,
    opt_state_dtype="bf16",          # 398B: see DESIGN.md memory policy
    # SSM-dominated; only 9 attention layers hold KV -> long_500k runs.
    shapes=lm_shapes(long_ok=True),
    source="arXiv:2403.19887; hf",
)
