"""Architecture configuration schema.

Every assigned architecture is a :class:`ModelConfig`: a stack of *stages*,
each stage a repeated *period* of layers, each layer a tuple of sublayer
kinds.  Examples:

* dense transformer:   stages = [ (("attn","mlp"),) × 1 period, n_periods=L ]
* gemma3 5:1 pattern:  period = 5×("attn_local","mlp") + 1×("attn","mlp")
* jamba 1:7 + MoE:     period of 8 mamba/attn layers with alternating moe
* xlstm:               period = 7×("mlstm",) + 1×("slstm",)

``shapes`` lists the assigned (shape-name → ShapeCfg) cells incl. skip flags.
Reduced smoke variants come from :meth:`ModelConfig.tiny`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

Layer = Tuple[str, ...]           # e.g. ("attn", "mlp")
Period = Tuple[Layer, ...]

VALID_SUBLAYERS = {"attn", "attn_local", "mlp", "moe", "mamba", "mlstm", "slstm"}


@dataclasses.dataclass(frozen=True)
class Stage:
    period: Period
    n_periods: int

    @property
    def n_layers(self) -> int:
        return len(self.period) * self.n_periods


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str                     # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                     # "train" | "prefill" | "decode"
    skip: bool = False
    skip_reason: str = ""


def lm_shapes(*, long_ok: bool, long_reason: str = "pure full attention") -> Tuple[ShapeCfg, ...]:
    return (
        ShapeCfg("train_4k", 4096, 256, "train"),
        ShapeCfg("prefill_32k", 32768, 32, "prefill"),
        ShapeCfg("decode_32k", 32768, 128, "decode"),
        ShapeCfg(
            "long_500k", 524288, 1, "decode",
            skip=not long_ok,
            skip_reason="" if long_ok else f"long_500k needs sub-quadratic attention; {long_reason}",
        ),
    )


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                                  # dense | moe | hybrid | ssm | audio | vlm
    stages: Tuple[Stage, ...]
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # attention details
    qk_norm: bool = False
    window: Optional[int] = None                 # sliding window for attn_local
    rope_theta: float = 10000.0
    mrope_sections: Optional[Tuple[int, int, int]] = None
    attn_shard: str = "kv"                       # "kv" | "group": which head axis TP shards
    # mlp / norm
    activation: str = "silu"                     # silu (SwiGLU) | gelu (GeGLU)
    norm: str = "rms"                            # rms | nonparametric
    embed_scale: bool = False                    # gemma: x *= sqrt(d_model)
    tie_embeddings: bool = True
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # mamba
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    # input frontend: "tokens" or "embeddings" (audio/vlm stub frontends)
    input_mode: str = "tokens"
    # dtypes
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    # optimizer-state policy (see DESIGN.md): "fp32" | "bf16"
    opt_state_dtype: str = "fp32"
    # assigned shapes
    shapes: Tuple[ShapeCfg, ...] = ()
    source: str = ""

    # ------------------------------------------------------------- derived
    @property
    def n_layers(self) -> int:
        return sum(s.n_layers for s in self.stages)

    @property
    def group(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def sublayer_kinds(self) -> set:
        kinds = set()
        for st in self.stages:
            for layer in st.period:
                kinds.update(layer)
        return kinds

    def has_attention(self) -> bool:
        return bool(self.sublayer_kinds() & {"attn", "attn_local"})

    def shape(self, name: str) -> ShapeCfg:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(name)

    def validate(self) -> None:
        assert self.n_heads % self.n_kv_heads == 0, "GQA group must divide"
        for st in self.stages:
            for layer in st.period:
                for sub in layer:
                    assert sub in VALID_SUBLAYERS, sub
        if self.is_moe:
            assert "moe" in self.sublayer_kinds()
        assert self.attn_shard in ("kv", "group")

    # --------------------------------------------------------------- param count
    def param_count(self) -> int:
        """Exact parameter count from the config (used for MODEL_FLOPS)."""
        d, Hd = self.d_model, self.head_dim
        H, KVH = self.n_heads, self.n_kv_heads
        n = self.vocab_size * d                      # embeddings (tied head)
        if not self.tie_embeddings:
            n += self.vocab_size * d
        counts = {
            "attn": d * H * Hd + 2 * d * KVH * Hd + H * Hd * d
            + (2 * Hd if self.qk_norm else 0) + d,
            "attn_local": d * H * Hd + 2 * d * KVH * Hd + H * Hd * d
            + (2 * Hd if self.qk_norm else 0) + d,
            "mlp": 3 * d * self.d_ff + d,
            "moe": d * self.n_experts
            + 3 * self.n_experts * d * self.moe_d_ff + d,
            "mamba": 0,
            "mlstm": 0,
            "slstm": 0,
        }
        di = self.mamba_expand * d
        dr = max(1, d // 16)
        ds = self.mamba_d_state
        counts["mamba"] = (
            d * 2 * di + self.mamba_d_conv * di + di
            + di * (dr + 2 * ds) + dr * di + di + di * ds + di + di * d + d
        )
        counts["mlstm"] = 3 * d * H * (d // H) + d * H * 2 + H * 2 + H * (d // H) * d + (d // H) + d
        counts["slstm"] = d * 4 * d + d * 4 * d + 4 * d + d * d + d
        for st in self.stages:
            for layer in st.period:
                for sub in layer:
                    n += counts[sub] * st.n_periods
        n += d                                        # final norm
        return n

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top_k of n_experts)."""
        if not self.is_moe:
            return self.param_count()
        moe_total = 0
        for st in self.stages:
            for layer in st.period:
                for sub in layer:
                    if sub == "moe":
                        moe_total += st.n_periods
        dense_equiv = self.param_count() - moe_total * (
            3 * self.n_experts * self.d_model * self.moe_d_ff
        )
        return dense_equiv + moe_total * 3 * self.top_k * self.d_model * self.moe_d_ff

    # ---------------------------------------------------------------- tiny
    def tiny(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        small_stages = tuple(
            Stage(period=s.period, n_periods=min(s.n_periods, 1)) for s in self.stages[:2]
        )
        kv = min(self.n_kv_heads, 2)
        heads = kv * min(self.group, 2)
        return dataclasses.replace(
            self,
            name=self.name + "-tiny",
            stages=small_stages,
            d_model=64,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=16,
            mrope_sections=(4, 2, 2) if self.mrope_sections else None,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            window=min(self.window, 32) if self.window else None,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            moe_d_ff=32 if self.moe_d_ff else 0,
            capacity_factor=4.0,     # drop-free at smoke scale: decode≡prefill
            mamba_d_state=8,
            dtype="float32",
            param_dtype="float32",
            shapes=(
                ShapeCfg("train_tiny", 32, 2, "train"),
                ShapeCfg("prefill_tiny", 32, 2, "prefill"),
                ShapeCfg("decode_tiny", 64, 2, "decode"),
            ),
        )


def dense_stages(n_layers: int) -> Tuple[Stage, ...]:
    return (Stage(period=(("attn", "mlp"),), n_periods=n_layers),)
