"""musicgen-large [audio] — 48L d_model=2048 32H (MHA kv=32) d_ff=8192 vocab=2048.

Decoder-only over EnCodec tokens; the EnCodec frontend is a STUB —
``input_specs()`` provides precomputed frame embeddings (input_mode =
"embeddings").  [arXiv:2306.05284; hf]
"""
from .base import ModelConfig, dense_stages, lm_shapes

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    stages=dense_stages(48),
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    activation="gelu",
    attn_shard="kv",
    tie_embeddings=False,
    input_mode="embeddings",
    shapes=lm_shapes(long_ok=False),
    source="arXiv:2306.05284; hf",
)
