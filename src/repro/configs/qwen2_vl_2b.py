"""qwen2-vl-2b [vlm] — 28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.

M-RoPE (3-D position ids), dynamic resolution; the vision frontend is a
STUB — ``input_specs()`` provides precomputed patch embeddings.
[arXiv:2409.12191; hf]
"""
from .base import ModelConfig, dense_stages, lm_shapes

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    stages=dense_stages(28),
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    mrope_sections=(16, 24, 24),     # head_dim/2 = 64 split over (t, h, w)
    activation="silu",
    attn_shard="group",              # kv=2: TP shards the 6 q-head groups
    tie_embeddings=True,
    input_mode="embeddings",
    shapes=lm_shapes(long_ok=False),
    source="arXiv:2409.12191; hf",
)
