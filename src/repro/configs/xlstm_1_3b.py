"""xlstm-1.3b [ssm] — 48L d_model=2048 4H d_ff=0 vocab=50304.

sLSTM + mLSTM blocks (7:1 mLSTM:sLSTM periods); no separate FFN (d_ff=0,
blocks carry their own projections).  [arXiv:2405.04517; unverified]
"""
from .base import ModelConfig, Stage, lm_shapes

_PERIOD = (
    ("mlstm",),
    ("mlstm",),
    ("mlstm",),
    ("mlstm",),
    ("mlstm",),
    ("mlstm",),
    ("mlstm",),
    ("slstm",),
)

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    stages=(Stage(period=_PERIOD, n_periods=6),),
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    head_dim=512,
    d_ff=0,
    vocab_size=50304,
    activation="silu",
    attn_shard="kv",
    tie_embeddings=True,
    # Pure recurrent state (O(1) per token): long_500k runs.
    shapes=lm_shapes(long_ok=True),
    source="arXiv:2405.04517; unverified",
)
