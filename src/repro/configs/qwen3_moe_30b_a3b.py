"""qwen3-moe-30b-a3b [moe] — 48L d_model=2048 32H (GQA kv=4) d_ff=768 vocab=151936.

MoE 128 experts top-8 (fine-grained, d_ff=768 per expert), qk-norm.
[hf:Qwen/Qwen3-30B-A3B; hf]
"""
from .base import ModelConfig, Stage, lm_shapes

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    stages=(Stage(period=(("attn", "moe"),), n_periods=48),),
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    n_experts=128,
    top_k=8,
    moe_d_ff=768,
    activation="silu",
    attn_shard="kv",                 # kv=4 over 16-way TP: padded; see §Perf
    tie_embeddings=False,
    shapes=lm_shapes(long_ok=False),
    source="hf:Qwen/Qwen3-30B-A3B; hf",
)
