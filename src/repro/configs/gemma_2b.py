"""gemma-2b [dense] — 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000.

GeGLU, head_dim=256, MQA.  [arXiv:2403.08295; hf]
"""
from .base import ModelConfig, dense_stages, lm_shapes

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    stages=dense_stages(18),
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    activation="gelu",
    embed_scale=True,
    attn_shard="group",       # MQA: TP shards q-head groups, KV replicated
    tie_embeddings=True,
    shapes=lm_shapes(long_ok=False),
    source="arXiv:2403.08295; hf",
)
