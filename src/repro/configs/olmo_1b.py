"""olmo-1b [dense] — 16L d_model=2048 16H (MHA kv=16) d_ff=8192 vocab=50304.

Non-parametric LayerNorm.  [arXiv:2402.00838; hf]
"""
from .base import ModelConfig, dense_stages, lm_shapes

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    stages=dense_stages(16),
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=8192,
    vocab_size=50304,
    norm="nonparametric",
    activation="silu",
    attn_shard="kv",
    tie_embeddings=True,
    shapes=lm_shapes(long_ok=False),
    source="arXiv:2402.00838; hf",
)
