"""dbrx-132b [moe] — 40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352.

MoE 16 experts top-4, fine-grained.  [hf:databricks/dbrx-base; unverified]
"""
from .base import ModelConfig, Stage, lm_shapes

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    stages=(Stage(period=(("attn", "moe"),), n_periods=40),),
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    n_experts=16,
    top_k=4,
    moe_d_ff=10752,
    activation="silu",
    attn_shard="kv",
    tie_embeddings=False,
    opt_state_dtype="bf16",          # 132B: fp32 m/v would not fit one pod
    shapes=lm_shapes(long_ok=False),
    source="hf:databricks/dbrx-base; unverified",
)
