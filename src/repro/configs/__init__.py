"""Architecture registry: ``get_config(name)`` / ``--arch <id>``."""
from __future__ import annotations

from typing import Dict

from .base import ModelConfig, ShapeCfg, Stage, dense_stages, lm_shapes
from . import (
    dbrx_132b,
    gemma3_27b,
    gemma_2b,
    jamba_1_5_large_398b,
    musicgen_large,
    olmo_1b,
    qwen2_vl_2b,
    qwen3_14b,
    qwen3_moe_30b_a3b,
    xlstm_1_3b,
)

ARCHS: Dict[str, ModelConfig] = {
    cfg.name: cfg
    for cfg in (
        qwen3_14b.CONFIG,
        gemma_2b.CONFIG,
        gemma3_27b.CONFIG,
        olmo_1b.CONFIG,
        musicgen_large.CONFIG,
        qwen2_vl_2b.CONFIG,
        dbrx_132b.CONFIG,
        qwen3_moe_30b_a3b.CONFIG,
        jamba_1_5_large_398b.CONFIG,
        xlstm_1_3b.CONFIG,
    )
}


def get_config(name: str) -> ModelConfig:
    if name.endswith("-tiny"):
        return ARCHS[name[: -len("-tiny")]].tiny()
    return ARCHS[name]


def arch_names() -> list:
    return list(ARCHS.keys())


__all__ = ["ARCHS", "get_config", "arch_names", "ModelConfig", "ShapeCfg", "Stage",
           "dense_stages", "lm_shapes"]
