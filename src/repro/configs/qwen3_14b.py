"""qwen3-14b [dense] — 40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936.

qk_norm + GQA.  [hf:Qwen/Qwen3-8B; hf]
"""
from .base import ModelConfig, dense_stages, lm_shapes

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    stages=dense_stages(40),
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    activation="silu",
    attn_shard="kv",
    tie_embeddings=False,
    shapes=lm_shapes(long_ok=False),
    source="hf:Qwen/Qwen3-8B; hf",
)
