"""gemma3-27b [dense] — 62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144.

5:1 local:global interleave (sliding window 1024), 128k context, qk-norm.
62 = 10 full (LLLLLG) periods + 2 trailing local layers.
[hf:google/gemma-3-1b-pt; unverified]
"""
from .base import ModelConfig, Stage, lm_shapes

_PERIOD = (
    ("attn_local", "mlp"),
    ("attn_local", "mlp"),
    ("attn_local", "mlp"),
    ("attn_local", "mlp"),
    ("attn_local", "mlp"),
    ("attn", "mlp"),
)

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    stages=(
        Stage(period=_PERIOD, n_periods=10),
        Stage(period=(("attn_local", "mlp"), ("attn_local", "mlp")), n_periods=1),
    ),
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    qk_norm=True,
    window=1024,
    rope_theta=1_000_000.0,
    activation="gelu",
    embed_scale=True,
    attn_shard="kv",
    tie_embeddings=True,
    # 52/62 layers are window-bounded; global layers SP-shard their KV.
    shapes=lm_shapes(long_ok=True),
    source="hf:google/gemma-3-1b-pt; unverified",
)
