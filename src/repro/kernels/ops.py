"""Jit'd public entry points for the Pallas kernels.

Backend routing, decided at trace time:

* **TPU** — the Pallas kernels compile natively.
* **CPU/GPU with ``REPRO_INTERPRET=1``** — the kernels execute in
  ``interpret=True`` mode, which runs the kernel body in Python for
  bit-correct validation against ``ref.py`` (this is what the test suite
  pins; see ``tests/conftest.py``).
* **CPU/GPU otherwise** — the pure-jnp oracles from ``ref.py``: identical
  semantics, XLA-vectorized, and orders of magnitude faster than the Python
  interpreter.  This is what production hot paths (the DeltaCR dump
  pipeline, benchmarks) get on non-TPU hosts.
* ``REPRO_FORCE_REF=1`` — bypass Pallas entirely everywhere (escape hatch).

The env vars are read when a call first traces for a given shape; set them
before the first call (the benchmarks and conftest both do).
"""
from __future__ import annotations

import functools
import os
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ref as _ref
from .delta_apply import delta_apply as _delta_apply_kernel
from .delta_diff import delta_diff as _delta_diff_kernel
from .delta_fused import delta_fused as _delta_fused_kernel
from .page_copy import page_copy as _page_copy_kernel
from .page_copy import page_copy_stacked as _page_copy_stacked_kernel
from .paged_attention import paged_attention as _paged_attention_kernel
from .ref import CHECKSUM_LANES

__all__ = [
    "paged_attention",
    "page_copy",
    "page_copy_stacked",
    "delta_diff",
    "delta_apply",
    "delta_compact",
    "delta_encode",
    "fused_encode",
    "shard_block_encode",
    "chunk_checksums_device",
    "chunk_checksums_host",
    "device_fetch",
    "start_host_fetch",
    "start_shard_fetch",
    "shard_fetch",
    "shard_fetch_assemble",
    "use_interpret",
    "CHECKSUM_LANES",
]


def use_interpret() -> bool:
    """Pallas interpret mode everywhere but real TPU backends."""
    return jax.default_backend() != "tpu"


def _force_ref() -> bool:
    return os.environ.get("REPRO_FORCE_REF", "0") == "1"


def _use_kernel() -> bool:
    """Native Pallas on TPU; interpret-mode Pallas only when pinned."""
    if _force_ref():
        return False
    return jax.default_backend() == "tpu" or os.environ.get("REPRO_INTERPRET", "0") == "1"


@functools.partial(jax.jit, static_argnames=("scale",))
def _paged_attention_jit(q, k_pages, v_pages, page_table, seq_lens, scale):
    if not _use_kernel():
        return _ref.paged_attention_ref(q, k_pages, v_pages, page_table, seq_lens, scale=scale)
    return _paged_attention_kernel(
        q, k_pages, v_pages, page_table, seq_lens, scale=scale, interpret=use_interpret()
    )


def paged_attention(q, k_pages, v_pages, page_table, seq_lens, *, scale=None):
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    return _paged_attention_jit(q, k_pages, v_pages, page_table, seq_lens, float(scale))


@jax.jit
def _page_copy_jit(pool, src_idx, dst_idx):
    if not _use_kernel():
        return _ref.page_copy_ref(pool, src_idx, dst_idx)
    return _page_copy_kernel(pool, src_idx, dst_idx, interpret=use_interpret())


def page_copy(pool, src_idx, dst_idx):
    return _page_copy_jit(pool, src_idx, dst_idx)


@jax.jit
def _page_copy_stacked_jit(pool, src_idx, dst_idx):
    if not _use_kernel():
        return _ref.page_copy_stacked_ref(pool, src_idx, dst_idx)
    return _page_copy_stacked_kernel(pool, src_idx, dst_idx, interpret=use_interpret())


def page_copy_stacked(pool, src_idx, dst_idx):
    return _page_copy_stacked_jit(pool, src_idx, dst_idx)


@jax.jit
def _delta_diff_jit(old, new):
    if not _use_kernel():
        return _ref.delta_diff_ref(old, new)
    return _delta_diff_kernel(old, new, interpret=use_interpret())


def delta_diff(old, new):
    return _delta_diff_jit(old, new)


@jax.jit
def _delta_apply_jit(base, data, idx):
    if not _use_kernel():
        return _ref.delta_apply_ref(base, data, idx)
    return _delta_apply_kernel(base, data, idx, interpret=use_interpret())


def delta_apply(base, data, idx):
    return _delta_apply_jit(base, data, idx)


@functools.partial(jax.jit, static_argnames=("max_changed",))
def delta_compact(new, dirty, max_changed: int):
    """Fixed-capacity compaction of dirty chunks (pure jnp; shape-static)."""
    return _ref.delta_compact_ref(new, dirty, max_changed)


@functools.partial(jax.jit, static_argnames=("max_changed",))
def delta_encode(old, new, max_changed: int):
    """diff + compact in one jit: (data, idx, count).

    The dump-pipeline hot path: one fused dispatch per tensor, returning the
    fixed-capacity compacted dirty chunks so the host moves O(delta) bytes.
    """
    dirty = (
        _delta_diff_kernel(old, new, interpret=use_interpret())
        if _use_kernel()
        else _ref.delta_diff_ref(old, new)
    )
    return _ref.delta_compact_ref(new, dirty, max_changed)


@functools.partial(jax.jit, static_argnames=("max_changed",))
def fused_encode(old, new, max_changed: int):
    """diff + compact + checksum in ONE kernel pass: (data, idx, count, sums).

    The adaptive dump pipeline's fused hot path — dirty bytes are read once
    on device and come back with 4-lane uint32 integrity checksums
    (``ref.chunk_checksums_ref`` lanes) that the drain stage can verify
    against the DMA'd bytes on host.  Contract (shapes, slot order, -1 idx
    padding, count-over-capacity overflow signal) is identical to
    ``delta_encode`` plus the sums output; ``ref.fused_encode_ref`` is the
    bit-exact oracle.
    """
    if not _use_kernel():
        return _ref.fused_encode_ref(old, new, max_changed)
    return _delta_fused_kernel(
        old, new, max_changed=max_changed, interpret=use_interpret()
    )


@functools.partial(jax.jit, static_argnames=("counts", "tile", "max_changed"))
def shard_block_encode(old, new, counts, tile, max_changed: int):
    """Block-native diff + compact for one shard part.

    Same (data, idx, count) contract as ``delta_encode``, but over the
    shard's NATIVE block layout instead of a
    materialized tile grid: per-tile dirtiness is a compare + reduce (one
    read of old and new, nothing written back), and only the ``max_changed``
    dirty tiles' bytes are extracted — each as the row-major tile bitcast to
    uint8, bit-identical to the matching ``_device_tile_grid`` row.  Device
    work is O(state) reads + O(delta) writes, where the grid path pays two
    O(state) byte-transposes per dump (old + new) before it ever diffs.
    """
    nd = len(counts)
    inter: list = []
    for c, t in zip(counts, tile):
        inter.extend((c, t))
    neq = (old != new).reshape(inter)
    dirty = jnp.any(neq, axis=tuple(2 * i + 1 for i in range(nd))).reshape(-1)
    count = jnp.sum(dirty.astype(jnp.int32))
    # ascending order, -1 padding at the tail, first-capacity overflow drop:
    # the exact delta_compact_ref slot contract
    idx = jnp.nonzero(dirty, size=max_changed, fill_value=-1)[0].astype(jnp.int32)
    # extract the selected tiles as a flat gather — work ∝ max_changed tiles,
    # never an O(block) tile-grid transpose.  Element offsets of one tile
    # (row-major over the tile, static) + the tile's base offset give each
    # row's exact element indices in the native block.
    block_shape = tuple(c * t for c, t in zip(counts, tile))
    estrides = np.ones(nd, np.int64)
    for i in range(nd - 2, -1, -1):
        estrides[i] = estrides[i + 1] * block_shape[i + 1]
    tcoords = np.indices(tile).reshape(nd, -1)
    t_off = (tcoords * estrides[:, None]).sum(0)             # (tile_elems,)
    ccoords = jnp.unravel_index(jnp.maximum(idx, 0), counts)
    # int32 offsets: fine below 2**31 elements per shard block (8 GiB f32)
    base = sum(
        c.astype(jnp.int32) * np.int32(t * s)
        for c, t, s in zip(ccoords, tile, estrides)
    )
    flat_idx = base[:, None] + jnp.asarray(t_off, jnp.int32)[None, :]
    rows = jnp.take(new.reshape(-1), flat_idx)               # (cap, tile_elems)
    u8 = jax.lax.bitcast_convert_type(rows, jnp.uint8).reshape(max_changed, -1)
    data = jnp.where((idx >= 0)[:, None], u8, jnp.uint8(0))
    return data, idx, count


@jax.jit
def chunk_checksums_device(chunks):
    """Device-side ``ref.chunk_checksums_ref`` lanes over compacted rows.

    Drain calls this on the power-of-two fetch slice, so the integrity
    lanes cost O(fetched rows * chunk) instead of O(capacity * chunk) —
    the block-native encode never pays for checksums on rows it will not
    ship.
    """
    return _ref.chunk_checksums_ref(chunks)


# numpy mirror constants of ref.chunk_checksums_ref — kept in lockstep
_CS_MULT = np.uint32(2654435761)
_CS_ADD = np.uint32(40503)
_CS_XOR = np.uint32(2246822519)


def chunk_checksums_host(chunks: np.ndarray) -> np.ndarray:
    """Numpy mirror of ``ref.chunk_checksums_ref``: (N, C) → (N, 4) uint32.

    Used by the dump drain stage to validate fetched fused-kernel rows
    against the device-computed lanes without a jax round-trip — one
    vectorized pass at host memory bandwidth.
    """
    x = np.ascontiguousarray(chunks).astype(np.uint32)
    if x.ndim == 1:
        x = x[None, :]
    C = x.shape[-1]
    pos = np.arange(C, dtype=np.uint32)[None, :]
    w = pos * _CS_MULT + _CS_ADD
    s0 = np.sum(x, axis=-1, dtype=np.uint32)
    s1 = np.sum(x * (pos + np.uint32(1)), axis=-1, dtype=np.uint32)
    s2 = np.sum(x * w, axis=-1, dtype=np.uint32)
    s3 = np.sum((x + np.uint32(1)) * (w ^ _CS_XOR), axis=-1, dtype=np.uint32)
    return np.stack([s0, s1, s2, s3], axis=-1)


def start_host_fetch(*arrays) -> None:
    """Begin async device→host copies without blocking.

    On TPU this starts the DMA for each committed array so a later
    ``np.asarray`` finds the bytes already on host; the streaming dump
    engine calls it at encode time so the copy of window *k* overlaps the
    diff dispatch of window *k+1*.  Backends (or tracers) without
    ``copy_to_host_async`` make this a no-op — ``np.asarray`` then blocks
    as usual, which is still correct.
    """
    for a in arrays:
        fn = getattr(a, "copy_to_host_async", None)
        if fn is not None:
            try:
                fn()
            except Exception:
                pass  # best-effort: the blocking fetch below stays correct


def device_fetch(*arrays) -> List[np.ndarray]:
    """Materialize device arrays on host, overlapping the copies."""
    start_host_fetch(*arrays)
    return [np.asarray(a) for a in arrays]


# --------------------------------------------------------------------------
# shard-granular fetches (the gather-free dump path)
# --------------------------------------------------------------------------
def start_shard_fetch(*arrays) -> None:
    """Begin async device→host copies per addressable shard.

    The sharded analogue of :func:`start_host_fetch`: each shard's DMA
    starts from its own device, so no cross-device gather is dispatched.
    Arrays without shard structure fall back to the whole-array prestart."""
    for a in arrays:
        shards = getattr(a, "addressable_shards", None)
        if shards is None:
            start_host_fetch(a)
            continue
        for sh in shards:
            fn = getattr(sh.data, "copy_to_host_async", None)
            if fn is not None:
                try:
                    fn()
                except Exception:
                    pass  # best-effort: the blocking fetch stays correct


def shard_fetch(array) -> List[Tuple[Any, np.ndarray]]:
    """Explicit per-shard device→host fetch: ``[(device, host_block), ...]``.

    Uses ``jax.device_get`` on each shard's single-device block — never
    materializes the global array, so it is legal under a disallow
    transfer guard and moves each block exactly once from its own device.
    Unsharded inputs return a single ``(device_or_None, host_array)``."""
    import jax

    shards = getattr(array, "addressable_shards", None)
    if shards is None:
        dev = None
        devs = getattr(array, "devices", None)
        if devs is not None:
            ds = list(devs())
            dev = ds[0] if len(ds) == 1 else None
        return [(dev, np.asarray(jax.device_get(array)))]
    start_shard_fetch(array)
    out: List[Tuple[Any, np.ndarray]] = []
    seen = set()
    for sh in shards:
        key = tuple(
            (s.start or 0, s.stop if s.stop is not None else dim)
            for s, dim in zip(sh.index, array.shape)
        )
        if key in seen:
            continue  # replicated shard: one copy is enough
        seen.add(key)
        out.append((sh.device, np.asarray(jax.device_get(sh.data))))
    return out


def shard_fetch_assemble(array) -> np.ndarray:
    """Host materialization of a (possibly sharded) array, assembled from
    per-shard fetches — the full-payload fallback (digest/legacy dumps) for
    sharded state.  O(S) bytes move, but each byte leaves its own device
    exactly once and assembly happens in host memory, never on device."""
    shards = getattr(array, "addressable_shards", None)
    if shards is None:
        import jax

        return np.asarray(jax.device_get(array))
    import jax

    start_shard_fetch(array)
    out = np.empty(array.shape, dtype=np.dtype(str(array.dtype)))
    seen = set()
    for sh in shards:
        key = tuple(
            (s.start or 0, s.stop if s.stop is not None else dim)
            for s, dim in zip(sh.index, array.shape)
        )
        if key in seen:
            continue
        seen.add(key)
        out[sh.index] = np.asarray(jax.device_get(sh.data))
    return out
