"""Pallas TPU kernel: batched CoW page privatization (pool[dst] = pool[src]).

The CoW-fault / async-warm hot path.  When a forked session first appends to
a page it shares with its template, the allocator hands it a free page and
this kernel materializes the copy — ``n`` (src, dst) pairs per call so warm
batches privatize the whole hot set in one kernel launch.

The pool is donated (input/output aliased): pages not named in ``dst_idx``
are untouched, so the copy is in-place in HBM.  Index pairs are scalar-
prefetch operands — each grid step DMAs exactly one source page HBM→VMEM and
writes it back to the destination slot.  dst pages are distinct free pages
and src∩dst = ∅ (allocator invariant), so steps commute.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["page_copy", "page_copy_stacked"]


def _page_copy_kernel(src_idx_ref, dst_idx_ref, pool_ref, out_ref):
    del src_idx_ref, dst_idx_ref
    out_ref[...] = pool_ref[...]


def page_copy(
    pool: jax.Array,       # (P, page_size, KVH, D) — donated
    src_idx: jax.Array,    # (n,) int32
    dst_idx: jax.Array,    # (n,) int32, distinct, disjoint from src
    *,
    interpret: bool = False,
) -> jax.Array:
    """Returns the pool with pages copied; unreferenced pages unchanged."""
    P = pool.shape[0]
    n = src_idx.shape[0]
    block = (1,) + pool.shape[1:]

    in_spec = pl.BlockSpec(block, lambda j, s, d: (s[j],) + (0,) * (pool.ndim - 1))
    out_spec = pl.BlockSpec(block, lambda j, s, d: (d[j],) + (0,) * (pool.ndim - 1))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n,),
        in_specs=[in_spec],
        out_specs=out_spec,
    )
    return pl.pallas_call(
        _page_copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        input_output_aliases={2: 0},  # pool (3rd operand incl. scalars) -> out
        interpret=interpret,
    )(src_idx.astype(jnp.int32), dst_idx.astype(jnp.int32), pool)


def page_copy_stacked(
    pool: jax.Array,       # (N_periods, P, page_size, KVH, D) — donated
    src_idx: jax.Array,    # (n,) int32
    dst_idx: jax.Array,    # (n,) int32, distinct, disjoint from src
    *,
    interpret: bool = False,
) -> jax.Array:
    """Stacked-pool CoW materialization: ``pool[:, dst] = pool[:, src]``.

    The serving pools are stacked per scan period — shape
    ``(N_periods, P, psz, KVH, Hd)`` — so a batch of CoW faults across a
    decode step is one launch over a 2-D grid ``(pairs × periods)`` instead
    of a vmapped per-period sweep.  Each grid step DMAs one (period, page)
    block; the same disjointness invariant (dst are distinct free pages,
    src ∩ dst = ∅) makes every step commute.
    """
    N = pool.shape[0]
    n = src_idx.shape[0]
    block = (1, 1) + pool.shape[2:]
    tail = (0,) * (pool.ndim - 2)

    in_spec = pl.BlockSpec(block, lambda j, r, s, d: (r, s[j]) + tail)
    out_spec = pl.BlockSpec(block, lambda j, r, s, d: (r, d[j]) + tail)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n, N),
        in_specs=[in_spec],
        out_specs=out_spec,
    )
    return pl.pallas_call(
        _page_copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        input_output_aliases={2: 0},
        interpret=interpret,
    )(src_idx.astype(jnp.int32), dst_idx.astype(jnp.int32), pool)
