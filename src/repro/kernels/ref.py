"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic references the kernel sweeps assert against
(``tests/test_kernels.py``) and the portable fallbacks ``ops.py`` uses when
Pallas is unavailable.  Shapes follow the serving substrate:

* KV pool per layer: ``(num_pages, page_size, kv_heads, head_dim)``
* page table per session: ``(max_pages,)`` int32 page indices
* chunked host/device state: ``(num_chunks, chunk_elems)``
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "paged_attention_ref",
    "page_copy_ref",
    "delta_diff_ref",
    "delta_apply_ref",
    "delta_compact_ref",
    "chunk_checksums_ref",
    "fused_encode_ref",
    "CHECKSUM_LANES",
]

# 4-lane vectorized integrity checksum over chunk bytes (uint32 wraparound).
# NOT a content-address: the chunk store's dedupe/verify key stays blake2b
# (see chunk_store.chunk_digest).  These lanes exist so the fused dump
# kernel can emit a digest of every dirty chunk in the same pass that diffs
# and compacts it — the host then validates the DMA'd bytes against the
# device-computed lanes (bitrot/truncation on the device→host path), and
# the kernel-vs-oracle parity suite asserts them bit-exactly.
CHECKSUM_LANES = 4
_CS_MULT = 2654435761        # Knuth multiplicative-hash constant
_CS_ADD = 40503
_CS_XOR = 2246822519


def paged_attention_ref(
    q: jax.Array,            # (B, KVH, G, D)   query grouped by kv head
    k_pages: jax.Array,      # (P, page_size, KVH, D)
    v_pages: jax.Array,      # (P, page_size, KVH, D)
    page_table: jax.Array,   # (B, max_pages) int32
    seq_lens: jax.Array,     # (B,) int32 — tokens currently in cache
    *,
    scale: Optional[float] = None,
) -> jax.Array:
    """Decode-step attention reading K/V through a page table.

    Returns (B, KVH, G, D).  Positions ≥ seq_len are masked; table entries
    beyond the active page count may be arbitrary valid page ids.
    """
    B, KVH, G, D = q.shape
    P, page_size, _, _ = k_pages.shape
    max_pages = page_table.shape[1]
    if scale is None:
        scale = 1.0 / (D ** 0.5)

    # Gather pages: (B, max_pages, page_size, KVH, D) -> (B, S, KVH, D)
    k = k_pages[page_table]      # (B, max_pages, page_size, KVH, D)
    v = v_pages[page_table]
    S = max_pages * page_size
    k = k.reshape(B, S, KVH, D)
    v = v.reshape(B, S, KVH, D)

    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # scores: (B, KVH, G, S)
    scores = jnp.einsum("bhgd,bshd->bhgs", qf, kf)
    pos = jnp.arange(S)[None, :]                      # (1, S)
    mask = pos < seq_lens[:, None]                    # (B, S)
    scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", probs, vf)
    return out.astype(q.dtype)


def page_copy_ref(
    pool: jax.Array,         # (P, page_size, KVH, D) or any (P, ...) pool
    src_idx: jax.Array,      # (n,) int32
    dst_idx: jax.Array,      # (n,) int32
) -> jax.Array:
    """CoW privatization: pool[dst_idx[i]] = pool[src_idx[i]].

    dst indices are distinct free pages (the allocator guarantees it), and
    src/dst sets are disjoint, so copy order is irrelevant.
    """
    return pool.at[dst_idx].set(pool[src_idx])


def page_copy_stacked_ref(
    pool: jax.Array,         # (N_periods, P, page_size, KVH, D)
    src_idx: jax.Array,      # (n,) int32
    dst_idx: jax.Array,      # (n,) int32
) -> jax.Array:
    """Stacked-pool CoW: pool[:, dst_idx[i]] = pool[:, src_idx[i]]."""
    return pool.at[:, dst_idx].set(pool[:, src_idx])


def delta_diff_ref(old: jax.Array, new: jax.Array) -> jax.Array:
    """Per-chunk dirty bitmap: any element differs → True.  (N, C) -> (N,)."""
    return jnp.any(old != new, axis=-1)


def delta_compact_ref(
    new: jax.Array,          # (N, C)
    dirty: jax.Array,        # (N,) bool
    max_changed: int,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Pack dirty chunks into a fixed-capacity buffer.

    Returns (data (max_changed, C), idx (max_changed,) int32 with -1 padding,
    count ()).  Deterministic: dirty chunks keep ascending order.
    """
    N, C = new.shape
    positions = jnp.cumsum(dirty.astype(jnp.int32)) - 1          # slot per dirty chunk
    count = jnp.sum(dirty.astype(jnp.int32))
    slot = jnp.where(dirty, positions, max_changed)              # overflow slot dropped
    data = jnp.zeros((max_changed + 1, C), new.dtype).at[slot].set(new, mode="drop")
    idx = jnp.full((max_changed + 1,), -1, jnp.int32).at[slot].set(
        jnp.arange(N, dtype=jnp.int32), mode="drop"
    )
    return data[:max_changed], idx[:max_changed], count


def chunk_checksums_ref(chunks: jax.Array) -> jax.Array:
    """Per-row 4-lane uint32 checksums of an (N, C) chunk grid.

    Pure elementwise-multiply + row-sum in uint32 (wraparound) — the exact
    formulas the fused Pallas kernel evaluates per block, and mirrored in
    numpy by ``ops.chunk_checksums_host``.  Lane 0 is order-insensitive;
    lanes 1-3 weight by byte position so transpositions and shifts change
    the value.  Returns (N, CHECKSUM_LANES) uint32.
    """
    x = chunks.astype(jnp.uint32)
    C = x.shape[-1]
    pos = jax.lax.broadcasted_iota(jnp.uint32, (1, C), 1)
    w = pos * jnp.uint32(_CS_MULT) + jnp.uint32(_CS_ADD)
    s0 = jnp.sum(x, axis=-1, dtype=jnp.uint32)
    s1 = jnp.sum(x * (pos + jnp.uint32(1)), axis=-1, dtype=jnp.uint32)
    s2 = jnp.sum(x * w, axis=-1, dtype=jnp.uint32)
    s3 = jnp.sum((x + jnp.uint32(1)) * (w ^ jnp.uint32(_CS_XOR)), axis=-1, dtype=jnp.uint32)
    return jnp.stack([s0, s1, s2, s3], axis=-1)


def fused_encode_ref(
    old: jax.Array,          # (N, C)
    new: jax.Array,          # (N, C)
    max_changed: int,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Oracle for the fused dump kernel: diff + compact + checksum.

    Returns (data (max_changed, C), idx (max_changed,) int32 with -1
    padding, count () int32 of ALL dirty rows — count > max_changed means
    capacity overflow — and sums (max_changed, CHECKSUM_LANES) uint32,
    zeroed on unused slots).  Identical slot contents and ordering to
    ``delta_compact_ref``; the checksum of each valid slot is over the
    compacted row bytes.
    """
    dirty = delta_diff_ref(old, new)
    data, idx, count = delta_compact_ref(new, dirty, max_changed)
    sums = chunk_checksums_ref(data)
    sums = jnp.where((idx >= 0)[:, None], sums, jnp.uint32(0))
    return data, idx, count, sums


def delta_apply_ref(
    base: jax.Array,         # (N, C)
    data: jax.Array,         # (M, C) compacted dirty chunks
    idx: jax.Array,          # (M,) int32, -1 = padding
) -> jax.Array:
    """Scatter dirty chunks into base: base[idx[j]] = data[j] (idx>=0)."""
    safe = jnp.where(idx >= 0, idx, base.shape[0])               # pad rows dropped
    return base.at[safe].set(data, mode="drop")
