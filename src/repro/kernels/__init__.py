"""Pallas TPU kernels for the C/R hot paths the paper optimizes.

* ``paged_attention`` — decode attention through a CoW page table
  (block-table indirection; what makes fork-shared KV pages readable in place).
* ``page_copy`` — batched CoW page privatization (fault / async-warm path).
* ``delta_diff`` / ``delta_apply`` — dirty-chunk detection and scatter-back
  (the delta-dump and slow-restore paths).

Each kernel ships as ``<name>.py`` (pl.pallas_call + BlockSpec), with the
jit'd wrappers in ``ops.py`` and pure-jnp oracles in ``ref.py``.
"""
from . import ops, ref
from .ops import (
    chunk_checksums_host,
    delta_apply,
    delta_compact,
    delta_diff,
    delta_encode,
    fused_encode,
    page_copy,
    paged_attention,
)

__all__ = [
    "ops",
    "ref",
    "chunk_checksums_host",
    "delta_apply",
    "delta_compact",
    "delta_diff",
    "delta_encode",
    "fused_encode",
    "page_copy",
    "paged_attention",
]
