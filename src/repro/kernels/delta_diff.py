"""Pallas TPU kernel: blockwise dirty-chunk detection for delta dumps.

The checkpoint-dump hot path: compare the current generation of a chunked
tensor against its parent and emit a per-chunk dirty bitmap.  The dump then
moves only dirty chunks device→host ("duplicate only the changes").  One
grid step compares a (chunk_block × chunk_elems) tile in VMEM; the reduction
runs at VREG width and the bitmap lands in a (N, 1) int32 column.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["delta_diff"]


def _delta_diff_kernel(old_ref, new_ref, dirty_ref):
    neq = (old_ref[...] != new_ref[...]).astype(jnp.int32)
    dirty_ref[...] = jnp.max(neq, axis=1, keepdims=True)


def delta_diff(
    old: jax.Array,     # (N, C)
    new: jax.Array,     # (N, C)
    *,
    chunk_block: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """Per-chunk dirty bitmap, (N,) bool."""
    assert old.shape == new.shape and old.dtype == new.dtype
    N, C = old.shape
    block = min(chunk_block, N)
    grid = (pl.cdiv(N, block),)
    out = pl.pallas_call(
        _delta_diff_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, C), lambda i: (i, 0)),
            pl.BlockSpec((block, C), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, 1), jnp.int32),
        interpret=interpret,
    )(old, new)
    return out[:, 0].astype(jnp.bool_)
