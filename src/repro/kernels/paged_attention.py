"""Pallas TPU kernel: decode attention through a CoW page table.

The serving hot path that makes template-fork restores free on TPU: forked
sessions *share* KV pages, so the decode step must read K/V through each
session's page table rather than a contiguous cache.  The page table and
sequence lengths are scalar-prefetch operands — the BlockSpec index maps
resolve the page indirection at DMA-issue time, so only the pages a session
actually references move HBM→VMEM (block-table indirection, the TPU analogue
of reading through CoW page tables).

Layout:
  q          (B, KVH, G, D)   — queries grouped under their kv head (GQA)
  k/v pages  (P, page_size, KVH, D)
  page_table (B, max_pages)   int32, entries beyond the active count must be
                              valid page ids (the pool keeps page 0 reserved)
  seq_lens   (B,)             int32

Grid (B, KVH, max_pages); the page axis iterates fastest and carries a
flash-style running (m, l, acc) in VMEM scratch.  MXU work per step is the
(G × D) · (D × page_size) score matmul; page_size and D are chosen
128-multiples so K/V tiles are MXU/VREG aligned.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["paged_attention"]

_NEG_INF = -1e30
_LANES = 128


def _paged_attention_kernel(
    # scalar prefetch
    seq_lens_ref,      # (B,)
    page_table_ref,    # (B, max_pages)
    # blocks
    q_ref,             # (1, 1, G, D)
    k_ref,             # (1, page_size, 1, D)
    v_ref,             # (1, page_size, 1, D)
    o_ref,             # (1, 1, G, D)
    # scratch
    m_scratch,         # (G, _LANES) f32
    l_scratch,         # (G, _LANES) f32
    acc_scratch,       # (G, D) f32
    *,
    page_size: int,
    num_page_steps: int,
    scale: float,
):
    b = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch, _NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    seq_len = seq_lens_ref[b]
    page_start = i * page_size

    @pl.when(page_start < seq_len)  # skip fully-masked pages
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale                 # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)                   # (page_size, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )                                                            # (G, page_size)
        pos = page_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < seq_len, s, _NEG_INF)

        m_prev = m_scratch[:, :1]                                    # (G, 1)
        l_prev = l_scratch[:, :1]
        m_cur = jnp.max(s, axis=1, keepdims=True)                    # (G, 1)
        m_next = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_next)
        p = jnp.exp(s - m_next)                                      # (G, page_size)
        l_next = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        acc = acc_scratch[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scratch[...] = jnp.broadcast_to(m_next, m_scratch.shape)
        l_scratch[...] = jnp.broadcast_to(l_next, l_scratch.shape)
        acc_scratch[...] = acc

    @pl.when(i == num_page_steps - 1)
    def _finalize():
        l = l_scratch[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)                              # seq_len == 0 guard
        o_ref[0, 0] = (acc_scratch[...] / l).astype(o_ref.dtype)


def paged_attention(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    page_table: jax.Array,
    seq_lens: jax.Array,
    *,
    scale: Optional[float] = None,
    interpret: bool = False,
) -> jax.Array:
    """See module docstring.  Returns (B, KVH, G, D) in q.dtype."""
    B, KVH, G, D = q.shape
    P, page_size, KVH_k, D_k = k_pages.shape
    assert (KVH_k, D_k) == (KVH, D), (k_pages.shape, q.shape)
    assert v_pages.shape == k_pages.shape
    max_pages = page_table.shape[1]
    if scale is None:
        scale = 1.0 / (D ** 0.5)

    kernel = functools.partial(
        _paged_attention_kernel,
        page_size=page_size,
        num_page_steps=max_pages,
        scale=float(scale),
    )
    grid = (B, KVH, max_pages)
    q_spec = pl.BlockSpec((1, 1, G, D), lambda b, h, i, sl, pt: (b, h, 0, 0))
    kv_spec = pl.BlockSpec((1, page_size, 1, D), lambda b, h, i, sl, pt: (pt[b, i], 0, h, 0))
    o_spec = pl.BlockSpec((1, 1, G, D), lambda b, h, i, sl, pt: (b, h, 0, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=o_spec,
        scratch_shapes=[
            pltpu.VMEM((G, _LANES), jnp.float32),
            pltpu.VMEM((G, _LANES), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KVH, G, D), q.dtype),
        interpret=interpret,
    )(seq_lens.astype(jnp.int32), page_table.astype(jnp.int32), q, k_pages, v_pages)
