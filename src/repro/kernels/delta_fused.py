"""Pallas TPU kernel: fused diff + compaction + checksum for delta dumps.

The pre-fusion dump path touched every dirty byte three times: once in
``delta_diff`` (dirty bitmap), once in the compaction gather, and once for
the integrity digest.  This kernel does all three in a single pass over the
generation grids, so dirty bytes cross the memory hierarchy exactly once:

* per grid block, compare old vs new and reduce to a per-row dirty flag;
* scatter each dirty row into the next free slot of a fixed-capacity
  compaction buffer (ascending row order, deterministic — bit-identical to
  ``ref.delta_compact_ref``);
* emit 4-lane uint32 checksums of the row bytes in the same pass
  (``ref.chunk_checksums_ref`` formulas) so the host can validate the
  DMA'd bytes without re-reading the device grid.

The grid walks blocks sequentially (TPU grid semantics), carrying the
compaction cursor in the SMEM count output — revisited every step via a
constant index_map, exactly the accumulation pattern the Pallas guide
documents.  ``count`` totals ALL dirty rows, so ``count > max_changed``
signals capacity overflow (the caller falls back to a full-grid dump).

VMEM note: the compaction buffer lives in VMEM for the whole launch, so
callers bound ``max_changed * chunk_bytes`` (the pipeline falls back to the
unfused two-kernel path past its VMEM budget).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import CHECKSUM_LANES, chunk_checksums_ref

__all__ = ["delta_fused"]


def _fused_kernel(old_ref, new_ref, data_ref, idx_ref, cnt_ref, sums_ref, *, cap: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        # zero-filled unused slots / -1 idx padding: bit-identical layout to
        # the jnp oracle, so parity tests compare whole buffers
        data_ref[...] = jnp.zeros(data_ref.shape, data_ref.dtype)
        idx_ref[...] = jnp.full(idx_ref.shape, -1, jnp.int32)
        sums_ref[...] = jnp.zeros(sums_ref.shape, jnp.uint32)
        cnt_ref[0, 0] = 0

    old = old_ref[...]
    new = new_ref[...]
    B = old.shape[0]
    # one read of old+new: dirty reduction and the checksum lanes share it
    dirty = jnp.max((old != new).astype(jnp.int32), axis=1)      # (B,)
    sums = chunk_checksums_ref(new)                              # (B, LANES)

    def _row(j, cnt):
        d = dirty[j]

        @pl.when((d > 0) & (cnt < cap))
        def _emit():
            data_ref[pl.ds(cnt, 1), :] = jax.lax.dynamic_slice_in_dim(new, j, 1, axis=0)
            idx_ref[pl.ds(cnt, 1), :] = jnp.full((1, 1), i * B + j, jnp.int32)
            sums_ref[pl.ds(cnt, 1), :] = jax.lax.dynamic_slice_in_dim(sums, j, 1, axis=0)

        return cnt + d                 # count every dirty row, past cap too

    cnt_ref[0, 0] = jax.lax.fori_loop(0, B, _row, cnt_ref[0, 0])


def delta_fused(
    old: jax.Array,     # (N, C)
    new: jax.Array,     # (N, C)
    *,
    max_changed: int,
    chunk_block: int = 8,
    interpret: bool = False,
):
    """Fused diff+compact+checksum: (data, idx, count, sums).

    Same contract as ``ref.fused_encode_ref`` — data (max_changed, C) with
    dirty rows in ascending order, idx (max_changed,) int32 (-1 padding),
    count () int32 over all dirty rows, sums (max_changed, CHECKSUM_LANES)
    uint32 zeroed on unused slots.
    """
    assert old.shape == new.shape and old.dtype == new.dtype
    N, C = old.shape
    cap = int(max_changed)
    assert cap >= 1
    block = min(chunk_block, N)
    if N % block:
        # pad with identical zero rows: never dirty, never emitted
        pad = ((0, block - N % block), (0, 0))
        old = jnp.pad(old, pad)
        new = jnp.pad(new, pad)
    grid = (pl.cdiv(old.shape[0], block),)
    data, idx, count, sums = pl.pallas_call(
        lambda *refs: _fused_kernel(*refs, cap=cap),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, C), lambda i: (i, 0)),
            pl.BlockSpec((block, C), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((cap, C), lambda i: (0, 0)),
            pl.BlockSpec((cap, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((cap, CHECKSUM_LANES), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((cap, C), new.dtype),
            jax.ShapeDtypeStruct((cap, 1), jnp.int32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
            jax.ShapeDtypeStruct((cap, CHECKSUM_LANES), jnp.uint32),
        ],
        interpret=interpret,
    )(old, new)
    return data, idx[:, 0], count[0, 0], sums
