"""Pallas TPU kernel: scatter compacted dirty chunks into a base tensor.

The slow-path restore: a dump image arrives as (compacted dirty chunks,
chunk indices); this kernel scatters them into the parent-generation tensor
in place (donated base).  Index rows with ``idx == -1`` are padding from the
fixed-capacity compaction and must not write — the grid step visits a
sacrificial block and skips the store, leaving the aliased base intact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["delta_apply"]


def _delta_apply_kernel(idx_ref, data_ref, base_ref, out_ref):
    del base_ref
    j = pl.program_id(0)

    @pl.when(idx_ref[j] >= 0)
    def _():
        out_ref[...] = data_ref[...]


def delta_apply(
    base: jax.Array,     # (N, C) — donated
    data: jax.Array,     # (M, C)
    idx: jax.Array,      # (M,) int32, -1 padding
    *,
    interpret: bool = False,
) -> jax.Array:
    N, C = base.shape
    M = data.shape[0]

    def _safe(i, idx_ref):
        v = idx_ref[i]
        return jnp.where(v >= 0, v, 0)

    data_spec = pl.BlockSpec((1, C), lambda j, i: (j, 0))
    base_spec = pl.BlockSpec((1, C), lambda j, i: (_safe(j, i), 0))
    out_spec = pl.BlockSpec((1, C), lambda j, i: (_safe(j, i), 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(M,),
        in_specs=[data_spec, base_spec],
        out_specs=out_spec,
    )
    return pl.pallas_call(
        _delta_apply_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(base.shape, base.dtype),
        input_output_aliases={2: 0},  # base (3rd operand incl. scalar) -> out
        interpret=interpret,
    )(idx.astype(jnp.int32), data, base)
