"""Fault-tolerant trainer: DeltaState-backed checkpoints, restart, elastic.

The paper's change-based C/R, retargeted at the training control plane:

* **Coupled checkpoints.**  Every K steps the trainer snapshots the
  *(model+optimizer state, data-pipeline cursor)* pair — the training
  analogue of the coupled (filesystem, process) invariant.  The device
  snapshot is an HBM-side copy dispatched before the next step (so the step
  loop never blocks), then a background thread delta-encodes it into
  DeltaFS: unchanged chunks (frozen layers, stale expert shards, the int
  step counter...) are shared with the previous generation, and rollback to
  any retained step is an O(1) layer switch.
* **Restart.**  ``restore_latest`` rebuilds params/opt/data-cursor from the
  last *complete* generation (a crash mid-dump leaves the previous
  generation intact — layers freeze atomically).
* **Elastic.**  Checkpoints are host chunks, mesh-agnostic: restoring onto
  a different device count / batch split reshards via device_put with the
  new shardings (``reshard``).
* **Straggler mitigation.**  A step-time watchdog flags outliers
  (> factor × rolling median) and fires a mitigation callback (work
  re-balance hook; simulated multi-worker harness in tests).
* **Gradient compression.**  Optional int8 + error feedback on the
  (cross-pod) gradient reduction.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import faults
from repro.core.deltafs import DeltaFS
from repro.models.model import Model
from .data import DataConfig, PackedStream
from .optim import (
    OptimizerConfig,
    adamw_init,
    adamw_update,
    compress_grads,
    decompress_grads,
    error_feedback_init,
)

__all__ = ["TrainerConfig", "Trainer", "StragglerWatchdog"]


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 20
    keep_ckpts: int = 3
    microbatches: int = 1               # gradient accumulation
    compress_grads: bool = False        # int8 + error feedback
    donate: bool = False                # buffer donation (on-device training)
    log_every: int = 10
    straggler_factor: float = 3.0
    straggler_window: int = 16


class StragglerWatchdog:
    """Flags steps slower than factor × rolling median; fires mitigation."""

    def __init__(self, factor: float, window: int, on_straggler: Optional[Callable[[int, float], None]] = None):
        self.factor = factor
        self.times: deque = deque(maxlen=window)
        self.flagged: List[int] = []
        self.on_straggler = on_straggler

    def observe(self, step: int, dt: float) -> bool:
        is_straggler = False
        if len(self.times) >= 4:
            med = float(np.median(self.times))
            if dt > self.factor * med:
                is_straggler = True
                self.flagged.append(step)
                if self.on_straggler:
                    self.on_straggler(step, dt / med)
        self.times.append(dt)
        return is_straggler


class Trainer:
    def __init__(
        self,
        model: Model,
        opt_cfg: OptimizerConfig,
        data_cfg: DataConfig,
        trainer_cfg: TrainerConfig = TrainerConfig(),
        *,
        ckpt_fs: Optional[DeltaFS] = None,
        mesh=None,
        param_shardings=None,
    ):
        self.model = model
        self.opt_cfg = dataclasses.replace(
            opt_cfg,
            moment_dtype="bfloat16" if model.cfg.opt_state_dtype == "bf16" else "float32",
        )
        self.data_cfg = data_cfg
        self.cfg = trainer_cfg
        self.fs = ckpt_fs or DeltaFS(chunk_bytes=1 << 20)
        self.mesh = mesh
        self.param_shardings = param_shardings
        self.stream = PackedStream(data_cfg)
        self.ckpt_index: Dict[int, Any] = {}      # step -> DeltaFS layer config
        self._ckpt_threads: List[threading.Thread] = []
        self._ckpt_lock = threading.Lock()
        self.watchdog = StragglerWatchdog(trainer_cfg.straggler_factor, trainer_cfg.straggler_window)
        self.metrics_log: List[Dict[str, float]] = []
        self._build_step()

    # ------------------------------------------------------------- step fn
    def _build_step(self):
        model, opt_cfg, tcfg = self.model, self.opt_cfg, self.cfg

        def loss_of(params, batch):
            loss, metrics = model.loss_fn(params, batch)
            return loss, metrics

        def train_step(params, opt_state, err_buf, batch):
            if tcfg.microbatches > 1:
                mb = jax.tree.map(
                    lambda x: x.reshape((tcfg.microbatches, -1) + x.shape[1:]), batch
                )

                def acc(carry, mbatch):
                    gsum, lsum = carry
                    (loss, _), g = jax.value_and_grad(loss_of, has_aux=True)(params, mbatch)
                    return (jax.tree.map(jnp.add, gsum, g), lsum + loss), None

                zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (gsum, lsum), _ = jax.lax.scan(acc, (zeros, jnp.zeros(())), mb)
                grads = jax.tree.map(lambda g: g / tcfg.microbatches, gsum)
                loss = lsum / tcfg.microbatches
                metrics = {}
            else:
                (loss, metrics), grads = jax.value_and_grad(loss_of, has_aux=True)(params, batch)

            if tcfg.compress_grads:
                comp, err_buf = compress_grads(grads, err_buf)
                grads = decompress_grads(comp)

            params, opt_state, opt_metrics = adamw_update(params, grads, opt_state, opt_cfg)
            out_metrics = {"loss": loss, **opt_metrics}
            return params, opt_state, err_buf, out_metrics

        donate = (0, 1, 2) if tcfg.donate else ()
        self.train_step = jax.jit(train_step, donate_argnums=donate)

    # ----------------------------------------------------------------- init
    def init_state(self, seed: int = 0):
        params = self.model.init(jax.random.PRNGKey(seed))
        opt_state = adamw_init(params, self.opt_cfg)
        err_buf = (
            error_feedback_init(params) if self.cfg.compress_grads else jnp.zeros(())
        )
        return params, opt_state, err_buf

    # ------------------------------------------------------------------ run
    def run(
        self,
        params,
        opt_state,
        err_buf,
        *,
        start_step: int = 0,
        steps: Optional[int] = None,
        fail_at: Optional[int] = None,       # legacy shim over core.faults
    ):
        n = steps if steps is not None else self.cfg.steps
        # The train-path crash hook goes through the shared fault registry
        # (`trainer.step` fires once per loop iteration), so train crash
        # tests and C/R chaos tests use one deterministic fault model.  The
        # old kwarg survives as a shim: it arms a one-shot FaultError —
        # a RuntimeError, as before — on this run's fail_at-th step seam hit.
        plan = faults.active_plan()
        local_plan = None
        if fail_at is not None and fail_at >= start_step:
            local_plan = plan if plan is not None else faults.FaultPlan()
            local_plan.add(
                "trainer.step", after=local_plan.hits("trainer.step") + (fail_at - start_step) + 1
            )
            if plan is None:
                faults.install(local_plan)
        try:
            return self._run_loop(params, opt_state, err_buf, start_step=start_step, n=n)
        finally:
            if local_plan is not None and plan is None:
                faults.clear()

    def _run_loop(self, params, opt_state, err_buf, *, start_step: int, n: int):
        step = start_step
        while step < n:
            t0 = time.perf_counter()
            batch_np = self.stream.next_batch()
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            faults.fire("trainer.step")
            params, opt_state, err_buf, metrics = self.train_step(
                params, opt_state, err_buf, batch
            )
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self.watchdog.observe(step, dt)
            step += 1
            if step % self.cfg.log_every == 0 or step == n:
                self.metrics_log.append(
                    {"step": step, "loss": float(metrics["loss"]), "dt": dt}
                )
            if self.cfg.ckpt_every and step % self.cfg.ckpt_every == 0:
                self.checkpoint(step, params, opt_state)
        self.wait_checkpoints()
        return params, opt_state, err_buf, step

    # ----------------------------------------------------------- checkpoint
    def checkpoint(self, step: int, params, opt_state) -> None:
        """Coupled async checkpoint of (model, optimizer, data cursor).

        An HBM-side copy is dispatched inline (so the next donated step can't
        clobber the snapshot); serialization + delta-encode runs off-thread,
        masked by subsequent compute — the inference-masked-dump analogue.
        """
        snap_params = jax.tree.map(jnp.copy, params)
        snap_opt = jax.tree.map(jnp.copy, opt_state)
        stream_state = self.stream.state()

        def serialize():
            flat_p, _ = jax.tree_util.tree_flatten_with_path(snap_params)
            flat_o, _ = jax.tree_util.tree_flatten_with_path(snap_opt)
            with self._ckpt_lock:  # DeltaFS upper-layer writes must serialize
                for path, leaf in flat_p:
                    self.fs.write("ckpt/params/" + _pstr(path), np.asarray(leaf))
                for path, leaf in flat_o:
                    self.fs.write("ckpt/opt/" + _pstr(path), np.asarray(leaf))
                for name, arr in stream_state.items():
                    self.fs.write(f"ckpt/data/{name}", arr)
                self.fs.write("ckpt/meta/step", np.asarray([step], np.int64))
                config = self.fs.checkpoint()      # freeze: generation complete
                self.ckpt_index[step] = config
                self._prune()

        th = threading.Thread(target=serialize, name=f"ckpt-{step}", daemon=True)
        th.start()
        self._ckpt_threads.append(th)

    def _prune(self) -> None:
        while len(self.ckpt_index) > self.cfg.keep_ckpts:
            oldest = min(self.ckpt_index)
            cfg = self.ckpt_index.pop(oldest)
            self.fs.release_config(cfg)

    def wait_checkpoints(self) -> None:
        for th in self._ckpt_threads:
            th.join(timeout=120.0)
        self._ckpt_threads.clear()

    # --------------------------------------------------------------- restore
    def restore_latest(self, *, shardings=None):
        """Rebuild (params, opt_state, stream) from the newest complete
        generation; returns (params, opt_state, err_buf, step)."""
        self.wait_checkpoints()
        if not self.ckpt_index:
            raise FileNotFoundError("no checkpoints")
        step = max(self.ckpt_index)
        self.fs.switch(self.ckpt_index[step])
        ref_params, ref_opt, _ = jax.eval_shape(lambda s: self.init_state(s), 0)
        params = self._read_tree("ckpt/params/", ref_params, shardings)
        opt_state = self._read_tree("ckpt/opt/", ref_opt, None)
        self.stream.restore(
            {
                "cursor": self.fs.read("ckpt/data/cursor"),
                "buf": self.fs.read("ckpt/data/buf"),
            }
        )
        err_buf = (
            error_feedback_init(params) if self.cfg.compress_grads else jnp.zeros(())
        )
        return params, opt_state, err_buf, int(self.fs.read("ckpt/meta/step")[0])

    def _read_tree(self, prefix: str, ref_tree, shardings):
        flat_ref, treedef = jax.tree_util.tree_flatten_with_path(ref_tree)
        leaves = []
        flat_sh = treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(flat_ref)
        for (path, ref), sh in zip(flat_ref, flat_sh):
            host = self.fs.read(prefix + _pstr(path)).astype(ref.dtype)
            host = host.reshape(ref.shape)
            leaves.append(jax.device_put(host, sh) if sh is not None else jnp.asarray(host))
        return treedef.unflatten(leaves)

    # ------------------------------------------------------- disk persistence
    def save_checkpoints(self, path: str) -> int:
        """Persist all retained checkpoint generations to one file (chunks
        deduplicated across generations).  Cross-process restart companion of
        restore_latest."""
        from repro.core.persist import save_store

        self.wait_checkpoints()
        return save_store(self.fs, {str(s): c for s, c in self.ckpt_index.items()}, path)

    def load_checkpoints(self, path: str) -> None:
        from repro.core.persist import load_store

        fs, configs = load_store(path)
        self.fs = fs
        self.ckpt_index = {int(s): c for s, c in configs.items()}

    # ---------------------------------------------------------------- elastic
    def reshard(self, tree, new_shardings):
        """Elastic restart onto a different mesh: host-roundtrip reshard."""
        flat, treedef = jax.tree.flatten(tree)
        flat_sh = treedef.flatten_up_to(new_shardings)
        return treedef.unflatten(
            [jax.device_put(np.asarray(l), s) if s is not None else l for l, s in zip(flat, flat_sh)]
        )


def _pstr(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
