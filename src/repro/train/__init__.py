"""Training substrate: optimizer, data pipeline, fault-tolerant trainer."""
from .data import DataConfig, PackedStream, PrefetchLoader
from .optim import (OptimizerConfig, adamw_init, adamw_update, compress_grads,
                    cosine_schedule, decompress_grads, error_feedback_init,
                    global_norm)
from .trainer import StragglerWatchdog, Trainer, TrainerConfig

__all__ = ["DataConfig", "PackedStream", "PrefetchLoader", "OptimizerConfig",
           "adamw_init", "adamw_update", "compress_grads", "cosine_schedule",
           "decompress_grads", "error_feedback_init", "global_norm",
           "StragglerWatchdog", "Trainer", "TrainerConfig"]
