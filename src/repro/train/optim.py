"""AdamW with per-arch dtype policy, schedules, clipping, int8 compression.

Self-contained pytree optimizer (no optax dependency):

* ``adamw_init / adamw_update`` — decoupled weight decay, bias correction,
  global-norm clipping; moment dtype per the arch's ``opt_state_dtype``
  policy (fp32 default; bf16 for the ≥100B archs, see DESIGN.md).
* ``cosine_schedule`` — linear warmup + cosine decay.
* ``compress_grads / decompress_grads`` — int8 gradient quantization with a
  persistent error-feedback buffer, applied on the cross-pod all-reduce
  (the distributed-optimization trick; exercised by tests + ablation bench).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "OptimizerConfig",
    "adamw_init",
    "adamw_update",
    "cosine_schedule",
    "global_norm",
    "clip_by_global_norm",
    "compress_grads",
    "decompress_grads",
    "error_feedback_init",
]


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"       # "float32" | "bfloat16"


def cosine_schedule(cfg: OptimizerConfig) -> Callable[[jax.Array], jax.Array]:
    def schedule(step: jax.Array) -> jax.Array:
        step = step.astype(jnp.float32)
        warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
        t = jnp.clip(
            (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
        )
        cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)

    return schedule


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(tree: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


def adamw_init(params: Any, cfg: OptimizerConfig) -> Dict[str, Any]:
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _decay_mask(path_elems) -> bool:
    """No weight decay on norms/biases/1-d params."""
    path = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path_elems)
    return not any(tok in path for tok in ("norm", "bias", "b_if", "b_gates", "dt_bias"))


def adamw_update(
    params: Any,
    grads: Any,
    opt_state: Dict[str, Any],
    cfg: OptimizerConfig,
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = opt_state["step"] + 1
    lr = cosine_schedule(cfg)(step)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    paths_and_params, treedef = jax.tree_util.tree_flatten_with_path(params)
    g_leaves = treedef.flatten_up_to(grads)
    m_leaves = treedef.flatten_up_to(opt_state["m"])
    v_leaves = treedef.flatten_up_to(opt_state["v"])
    new_p, new_m, new_v = [], [], []
    for (path, p), g, m, v in zip(paths_and_params, g_leaves, m_leaves, v_leaves):
        gf = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + gf * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + jnp.square(gf) * (1 - b2)
        update = (m32 / c1) / (jnp.sqrt(v32 / c2) + cfg.eps)
        if _decay_mask(path):
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr * update).astype(p.dtype))
        new_m.append(m32.astype(mdt))
        new_v.append(v32.astype(mdt))
    new_state = {
        "m": treedef.unflatten(new_m),
        "v": treedef.unflatten(new_v),
        "step": step,
    }
    return treedef.unflatten(new_p), new_state, {"lr": lr, "grad_norm": gnorm}


# --------------------------------------------------------------------------
# int8 gradient compression with error feedback
# --------------------------------------------------------------------------


def error_feedback_init(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(grads: Any, error_buf: Any) -> Tuple[Any, Any]:
    """Quantize (grad + carried error) to int8 with per-tensor scale.

    Returns ((q, scale) tree, new_error_buf).  The error buffer carries the
    quantization residual into the next step (error feedback), which keeps
    convergence within noise of uncompressed SGD in practice."""

    g_leaves, treedef = jax.tree.flatten(grads)
    e_leaves = treedef.flatten_up_to(error_buf)
    qs, scales, errs = [], [], []
    for g, e in zip(g_leaves, e_leaves):
        x = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
        qi = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        qs.append(qi)
        scales.append(scale)
        errs.append(x - qi.astype(jnp.float32) * scale)
    comp = (treedef.unflatten(qs), treedef.unflatten(scales))
    return comp, treedef.unflatten(errs)


def decompress_grads(comp: Any) -> Any:
    qt, st = comp
    return jax.tree.map(lambda qi, s: qi.astype(jnp.float32) * s, qt, st)
