"""Synthetic data pipeline with sequence packing and a checkpointable cursor.

Production shape without external datasets: a deterministic document stream
(seeded Zipf-ish token documents of variable length), packed into fixed-
length training sequences with cross-document attention masking via EOD
boundaries, sharded by data-parallel rank.

The pipeline's **cursor** (document counter per rank) is part of the coupled
training checkpoint: restoring a run resumes the stream exactly where the
saved step left off — the (data, model) analogue of the paper's coupled
(filesystem, process) pair.  A background prefetch thread keeps one batch
ahead (overlapping host data work with the device step).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

__all__ = ["DataConfig", "PackedStream", "PrefetchLoader"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    n_ranks: int = 1
    rank: int = 0
    seed: int = 1234
    mean_doc_len: int = 512
    eod_id: int = 0


class PackedStream:
    """Deterministic packed-sequence stream with an explicit cursor."""

    def __init__(self, cfg: DataConfig, cursor: int = 0):
        assert cfg.global_batch % cfg.n_ranks == 0
        self.cfg = cfg
        self.cursor = int(cursor)              # documents consumed by this rank
        self._buf = np.empty((0,), np.int64)

    # ------------------------------------------------------------- stream
    def _doc(self, index: int) -> np.ndarray:
        """Deterministic document #index for this rank."""
        rng = np.random.default_rng(
            (self.cfg.seed, self.cfg.rank, index)
        )
        length = int(rng.integers(self.cfg.mean_doc_len // 4, self.cfg.mean_doc_len * 2))
        # Zipf-ish marginals make content-dedup / compression behave realistically
        toks = rng.zipf(1.3, size=length) % (self.cfg.vocab_size - 1) + 1
        return np.concatenate([toks.astype(np.int64), [self.cfg.eod_id]])

    def next_batch(self) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        per_rank = cfg.global_batch // cfg.n_ranks
        need = per_rank * (cfg.seq_len + 1)
        while self._buf.size < need:
            self._buf = np.concatenate([self._buf, self._doc(self.cursor)])
            self.cursor += 1
        flat = self._buf[:need].reshape(per_rank, cfg.seq_len + 1)
        self._buf = self._buf[need:]
        tokens = flat[:, :-1].astype(np.int32)
        labels = flat[:, 1:].astype(np.int32)
        labels = np.where(tokens == cfg.eod_id, -1, labels)  # don't predict across EOD
        return {"tokens": tokens, "labels": labels}

    # ----------------------------------------------------------- coupling
    def state(self) -> Dict[str, np.ndarray]:
        return {
            "cursor": np.asarray([self.cursor], np.int64),
            "buf": self._buf.copy(),
        }

    def restore(self, state: Dict[str, np.ndarray]) -> None:
        self.cursor = int(state["cursor"][0])
        self._buf = np.asarray(state["buf"], np.int64).copy()


class PrefetchLoader:
    """One-batch-ahead background prefetch (host/device overlap)."""

    def __init__(self, stream: PackedStream, depth: int = 2):
        self.stream = stream
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = False
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self) -> None:
        while not self._stop:
            batch = self.stream.next_batch()
            state = self.stream.state()
            try:
                self._q.put((batch, state), timeout=1.0)
            except queue.Full:
                if self._stop:
                    return
                self._q.put((batch, state))

    def __next__(self) -> Tuple[Dict[str, np.ndarray], Dict[str, np.ndarray]]:
        """Returns (batch, stream-state-after-batch) for coupled checkpoints."""
        return self._q.get()

    def stop(self) -> None:
        self._stop = True
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
