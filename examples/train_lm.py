"""End-to-end training driver: train an LM with DeltaState fault tolerance.

Trains a reduced olmo-family model on the synthetic packed stream, taking
coupled async checkpoints, then *kills* the run mid-flight and restarts from
the last complete generation — demonstrating the restart path end-to-end.

Defaults are sized for this CPU container (~12M params, 120 steps); scale
``--layers/--d-model/--steps`` up on real hardware (``--steps 300`` trains a
~100M model for a few hundred steps with the same code path).

    PYTHONPATH=src python examples/train_lm.py [--steps 120]
"""
import argparse
import dataclasses
import time

from repro.configs import get_config
from repro.configs.base import Stage
from repro.models import Model
from repro.train import DataConfig, OptimizerConfig, Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--d-ff", type=int, default=1024)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--vocab", type=int, default=4096)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a crash at this step to demo restart")
    args = ap.parse_args()

    base = get_config("olmo-1b").tiny()
    cfg = dataclasses.replace(
        base,
        name="train-lm-demo",
        stages=(Stage(period=(("attn", "mlp"),), n_periods=args.layers),),
        d_model=args.d_model,
        n_heads=4,
        n_kv_heads=4,
        head_dim=args.d_model // 4,
        d_ff=args.d_ff,
        vocab_size=args.vocab,
        mrope_sections=None,
    )
    model = Model(cfg)
    print(f"model: {model.param_count()/1e6:.1f}M params, {cfg.n_layers} layers")

    trainer = Trainer(
        model,
        OptimizerConfig(peak_lr=3e-4, warmup_steps=20, total_steps=args.steps),
        DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len, global_batch=args.batch),
        TrainerConfig(steps=args.steps, ckpt_every=20, log_every=10),
    )
    params, opt, err = trainer.init_state(0)

    fail_at = args.fail_at if args.fail_at is not None else (args.steps * 2) // 3
    t0 = time.time()
    try:
        params, opt, err, step = trainer.run(params, opt, err, fail_at=fail_at)
    except RuntimeError as exc:
        print(f"!! {exc} — restoring from the last complete checkpoint")
        params, opt, err, step = trainer.restore_latest()
        print(f"resumed at step {step} (data cursor restored with it)")
        params, opt, err, step = trainer.run(params, opt, err, start_step=step)
    dt = time.time() - t0

    losses = [f"{m['step']}:{m['loss']:.3f}" for m in trainer.metrics_log]
    print(f"finished {step} steps in {dt:.0f}s")
    print("loss curve:", " ".join(losses))
    stats = trainer.fs.store.stats
    print(
        f"checkpoint store: physical={stats.physical_bytes/1e6:.1f}MB "
        f"across {len(trainer.ckpt_index)} generations "
        f"(straggler flags: {trainer.watchdog.flagged})"
    )
    assert trainer.metrics_log[-1]["loss"] < trainer.metrics_log[0]["loss"], "loss must drop"
    print("OK")


if __name__ == "__main__":
    main()
