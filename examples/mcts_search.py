"""MCTS tree search over a *real* LM serving session with C/R-protected state.

An agent session (paged KV cache + sampling state) plus a repo filesystem is
explored with UCT: every expansion checkpoints, every selection rolls back.
Forked branches share KV pages copy-on-write.

    PYTHONPATH=src python examples/mcts_search.py [--iterations 20]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import CowArrayState, DeltaCR, DeltaFS, Sandbox, StateManager
from repro.models import Model
from repro.search import MCTS, MCTSConfig
from repro.serve import Engine, PagePool, PagedSession, SamplingParams


class LMAgentTask:
    """Actions = sampled continuations from the LM; the engine session *is*
    the process state (forked through the page pool)."""

    def __init__(self, engine: Engine, tokens_per_action: int = 4):
        self.engine = engine
        self.tokens_per_action = tokens_per_action

    def propose_actions(self, sandbox, rng_seed):
        rng = np.random.default_rng(rng_seed)
        return [int(s) for s in rng.integers(0, 1 << 30, size=3)]

    def apply_action(self, sandbox, action):
        sess: PagedSession = sandbox.proc
        sess.extras["rng_seed"] = np.asarray([action], np.int64)
        sess.extras["rng_counter"] = np.asarray([0], np.int64)
        for _ in range(self.tokens_per_action):
            self.engine.step([sess])
        # leave a durable trace of the trajectory in the repo
        sandbox.fs.write("repo/trajectory", np.asarray(sess.tokens, np.int64))

    replay_action = apply_action

    def evaluate(self, sandbox):
        sess: PagedSession = sandbox.proc
        toks = sess.tokens[-self.tokens_per_action :]
        return float(len(set(toks))) / max(len(toks), 1)     # diversity reward

    def is_terminal(self, sandbox):
        return sandbox.proc.seq_len > 96

    def is_readonly(self, action):
        return False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iterations", type=int, default=15)
    ap.add_argument("--arch", default="olmo-1b-tiny")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pool = PagePool(cfg, num_pages=1024, page_size=8, max_pages_per_session=32)
    engine = Engine(model, params, pool)

    fs = DeltaFS(chunk_bytes=4096)
    fs.write("repo/readme", np.arange(1000, dtype=np.int32))
    session = engine.new_session([1, 2, 3, 4, 5, 6, 7], SamplingParams(temperature=0.8))
    cr = DeltaCR(
        store=fs.store,
        restore_fn=lambda p: PagedSession.restore_from_payload(pool, p),
        template_pool_size=16,
    )
    sm = StateManager(Sandbox(fs, session), cr)
    task = LMAgentTask(engine)
    sm.action_applier = lambda sb, act: task.replay_action(sb, act)

    t0 = time.time()
    mcts = MCTS(sm, task, MCTSConfig(iterations=args.iterations, value_isolation=False, seed=7))
    st = mcts.run()
    cr.wait_dumps()
    best = mcts.best_leaf()
    print(
        f"{st.iterations} iterations in {time.time()-t0:.1f}s | nodes={st.nodes} "
        f"restores={st.restores} (fast={st.fast_restores}) best_value={st.best_value:.2f}"
    )
    print(f"CoW page copies: {pool.cow_copies}, warm-absorbed: {pool.warm_copies}")
    print(f"free pages: {pool.free_pages()}/{pool.num_pages}")
    if best is not None:
        sm.restore(best)
        print("best trajectory tokens:", sm.sandbox.proc.tokens[:24], "...")


if __name__ == "__main__":
    main()
