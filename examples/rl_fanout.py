"""RL training step with warm-template rollout fan-out (paper §6.2.2).

Each step: fork N rollout sessions from one warm template (page-table copy),
generate completions, score them, REINFORCE-update the policy, tear down.
The fork primitive keeps the accelerator busy: sandbox time is microseconds
against seconds of generation/training.

    PYTHONPATH=src python examples/rl_fanout.py [--steps 3 --rollouts 8]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import Model
from repro.search import fork_n, sync_gpu_occupation
from repro.serve import Engine, PagePool, SamplingParams
from repro.train.optim import OptimizerConfig, adamw_init, adamw_update


def reward_fn(tokens):
    """Toy reward: prefer token diversity in the completion."""
    return len(set(tokens)) / max(len(tokens), 1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=3)
    ap.add_argument("--rollouts", type=int, default=8)
    ap.add_argument("--gen-tokens", type=int, default=6)
    args = ap.parse_args()

    cfg = get_config("olmo-1b-tiny")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = OptimizerConfig(peak_lr=1e-4, warmup_steps=2, total_steps=100)
    opt_state = adamw_init(params, opt_cfg)
    pool = PagePool(cfg, num_pages=4096, page_size=8, max_pages_per_session=32)
    prompt = [3, 1, 4, 1, 5, 9, 2, 6]

    def reinforce_loss(p, tokens, advantage):
        toks = jnp.asarray([tokens], jnp.int32)
        hidden, _ = model.forward(p, toks[:, :-1], remat=False)
        from repro.models.model import L

        hidden = L.apply_norm(cfg.norm, p["final_norm"], hidden)
        logits = model._logits(p, hidden)
        logp = jax.nn.log_softmax(logits, axis=-1)
        gold = jnp.take_along_axis(logp, toks[:, 1:, None], axis=-1)[..., 0]
        return -advantage * jnp.mean(gold)

    grad_fn = jax.jit(jax.grad(reinforce_loss))

    for step in range(args.steps):
        engine = Engine(model, params, pool)
        template = engine.new_session(prompt, SamplingParams(temperature=1.0, seed=step))

        # --- fan-out: N forks from the warm template -----------------------
        t0 = time.perf_counter()
        children, fan = fork_n(template, args.rollouts)
        t_sandbox = time.perf_counter() - t0

        # --- rollouts (distinct RNG per child -> distinct trajectories) ----
        t0 = time.perf_counter()
        rewards = []
        for i, child in enumerate(children):
            child.extras["rng_seed"] = np.asarray([1000 * step + i], np.int64)
            child.extras["rng_counter"] = np.asarray([0], np.int64)
            engine.generate(child, args.gen_tokens)
            rewards.append(reward_fn(child.tokens[len(prompt):]))
        t_gen = time.perf_counter() - t0

        # --- REINFORCE update on advantage-weighted trajectories -----------
        t0 = time.perf_counter()
        baseline = float(np.mean(rewards))
        gsum = jax.tree.map(jnp.zeros_like, params)
        for child, r in zip(children, rewards):
            g = grad_fn(params, child.tokens, r - baseline)
            gsum = jax.tree.map(jnp.add, gsum, g)
        grads = jax.tree.map(lambda g: g / len(children), gsum)
        params, opt_state, info = adamw_update(params, grads, opt_state, opt_cfg)
        t_train = time.perf_counter() - t0

        for child in children:
            child.release()
        template.release()
        occ = sync_gpu_occupation(t_sandbox, t_gen, t_train)
        print(
            f"step {step}: fork_p50={fan.p50_ms:.3f}ms sandbox={t_sandbox*1e3:.1f}ms "
            f"gen={t_gen:.2f}s train={t_train:.2f}s occupation={occ:.3f} "
            f"mean_reward={baseline:.3f}"
        )
    print("OK")


if __name__ == "__main__":
    main()
