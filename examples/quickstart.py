"""Quickstart: DeltaState in 60 lines.

A sandbox is a coupled (DeltaFS filesystem, forkable process state) pair.
Checkpoints duplicate only deltas; rollback is O(1); dumps are async.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (
    CowArrayState,
    DeltaCR,
    DeltaFS,
    Sandbox,
    StateManager,
    reachability_gc,
)


def main():
    # --- build a sandbox: repo tensors (durable) + agent heap (ephemeral)
    fs = DeltaFS(chunk_bytes=4096)
    fs.write("repo/main.py", np.arange(50_000, dtype=np.int32))
    proc = CowArrayState({"heap": np.zeros(1_000_000, np.float32)}, hot_keys=("heap",))
    cr = DeltaCR(
        store=fs.store,
        restore_fn=lambda p: CowArrayState({k: v.copy() for k, v in p.items()}),
        template_pool_size=8,
    )
    sm = StateManager(Sandbox(fs, proc), cr)

    # --- checkpoint, mutate, checkpoint
    c1 = sm.checkpoint()                      # O(1) layer freeze + template fork
    sm.sandbox.fs.write("repo/main.py", np.ones(50_000, np.int32))
    sm.sandbox.proc.mutate("heap", lambda h: h.__setitem__(slice(0, 10), 1.0))
    c2 = sm.checkpoint()

    # --- rollback: coupled, millisecond-class, arbitrary target
    mode = sm.restore(c1)
    assert sm.sandbox.fs.read("repo/main.py")[0] == 0
    assert sm.sandbox.proc.get("heap")[0] == 0.0
    print(f"restored c1 via {mode} path")

    mode = sm.restore(c2)
    assert sm.sandbox.proc.get("heap")[0] == 1.0
    print(f"restored c2 via {mode} path")

    # --- value-time test isolation: side effects rolled back unconditionally
    def run_tests(sb):
        sb.fs.write("repo/__pycache__", np.zeros(8, np.int8))
        return 0.83

    value = sm.isolated_eval(run_tests)
    assert not sm.sandbox.fs.exists("repo/__pycache__")
    print(f"isolated eval -> {value}, side effects undone")

    # --- storage is delta-based
    cr.wait_dumps()
    stats = fs.store.stats
    print(f"physical={stats.physical_bytes/1e6:.2f} MB "
          f"logical={stats.logical_bytes/1e6:.2f} MB "
          f"(sharing={stats.logical_bytes/max(stats.physical_bytes,1):.1f}x)")
    reachability_gc(sm)
    print("OK")


if __name__ == "__main__":
    main()
